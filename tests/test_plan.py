"""Superstep-plan IR tests (`repro.core.plan`).

Four layers:

* plan structure: op lists under pull/naive, one op == one superstep
  (`len(plan.ops)` is the accounting contract), chain4's known shapes;
* the ``auto`` selector: per step, its plan must equal the cheaper of the
  hand-picked pull/naive plans (ties to pull) across the whole stdlib;
* the (executor × schedule) matrix in-process: partitioned(S=1) naive and
  auto bit-match the fused dense executor with identical plan-derived
  superstep counts — closing the ROADMAP "pull schedule only" asymmetry;
* the CHAIN_MODE deprecation shim (module global → ``schedule=`` arg).

One 8-fake-device subprocess case (a single representative program, see
the ``subprocess_mesh`` marker) keeps the multi-shard naive collectives
honest without re-paying the full subprocess matrix.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import ast as past
from repro.core import codegen, compile_program, lower_step
from repro.core.analysis import iter_steps
from repro.core.plan import (
    MainCompute,
    ReadRound,
    RemoteUpdate,
    SCHEDULES,
    StepPlan,
)
from repro.graph import generators as G
from repro.pregel import run_bsp


def _steps(src, g, fields=None):
    cp = compile_program(src, g, initial_fields=fields)
    return [s for s in iter_steps(cp.prog) if isinstance(s, past.Step)]


def _setup(name, seed=3):
    fields = None
    if name == "sssp":
        g = G.erdos_renyi(40, 4.0, directed=True, weighted=True, seed=seed)
    elif name == "chain4":
        g = G.erdos_renyi(30, 2.0, directed=False, seed=seed)
        rng = np.random.default_rng(seed)
        fields = {"D": jnp.asarray(rng.integers(0, 30, 30), jnp.int32)}
    else:
        g = G.erdos_renyi(40, 3.0, directed=False, weighted=True, seed=seed)
    return g, fields


class TestPlanStructure:
    def test_chain4_pull_is_pointer_doubling(self):
        g, fields = _setup("chain4")
        (step,) = _steps(alg.CHAIN4, g, fields)
        plan = lower_step(step, schedule="pull")
        kinds = [type(op).__name__ for op in plan.ops]
        assert kinds == ["ReadRound", "ReadRound", "MainCompute"]
        # round 1 materializes D², round 2 composes D⁴ = D²∘D²
        assert plan.ops[0].chains[0].pattern == ("D", "D")
        assert plan.ops[1].chains[0].pattern == ("D",) * 4
        assert plan.ops[1].chains[0].prefix == ("D", "D")
        assert plan.ops[1].chains[0].suffix == ("D", "D")
        assert plan.read_rounds == 2 and plan.n_supersteps == 3

    def test_chain4_naive_is_request_reply_per_hop(self):
        g, fields = _setup("chain4")
        (step,) = _steps(alg.CHAIN4, g, fields)
        plan = lower_step(step, schedule="naive")
        rr = [op for op in plan.ops if isinstance(op, ReadRound)]
        # three hops (D², D³, D⁴), each a request+reply pair
        assert [op.kind for op in rr] == ["request", "reply"] * 3
        # each naive hop splits off the last field
        for op in rr:
            (ce,) = op.chains
            assert ce.prefix == ce.pattern[:-1] and ce.suffix == (ce.pattern[-1],)
        assert plan.n_supersteps == 7  # 6 read rounds + main (paper: naive)

    def test_remote_update_carries_write_descs(self):
        g, _ = _setup("sv")
        steps = _steps(alg.SV, g)
        body = steps[-1]  # the iteration body step (has the remote write)
        plan = lower_step(body, schedule="pull")
        (ru,) = [op for op in plan.ops if isinstance(op, RemoteUpdate)]
        assert ru.writes == (("D", "<?="),)
        assert plan.ops[-2] == MainCompute(emits_remote=True)

    def test_general_read_costs_read_rounds(self):
        """A computed-index ("general") read is one request/reply
        conversation in manual code and one gather round under pull; the
        plan charges those supersteps (chain-less rounds — the value is
        consumed inline in main), keeping the old STM charges AND making
        every executor actually dispatch what the model counts."""
        src = """
for v in V
    local A[v] := Id[v] % numV
    local B[v] := Id[v] * 2
end
for v in V
    local X[v] := B[(A[v] + 1) % numV]
end
"""
        g = G.erdos_renyi(24, 2.0, directed=False, seed=0)
        cp = compile_program(src, g)
        step = _steps(src, g)[-1]
        pull = lower_step(step, schedule="pull")
        naive = lower_step(step, schedule="naive")
        assert pull.read_rounds == 1 and not pull.ops[0].chains
        assert [op.kind for op in naive.ops[:-1]] == ["request", "reply"]
        # old STM charges hold and match execution on every executor
        dense, _, counts = cp.run()
        assert counts["pull_staged"] == 1 + 2  # init main + RR + main
        assert counts["naive"] == 1 + 3
        f0 = cp.init_fields()
        for sched in ("pull", "naive", "auto"):
            for placement, kw in (
                ("replicated", {}), ("partitioned", {"n_shards": 1}),
            ):
                res = run_bsp(
                    cp.prog, g, f0, schedule=sched, placement=placement, **kw
                )
                key = "pull_staged" if sched in ("pull", "auto") else "naive"
                assert res.supersteps == counts[key], (sched, placement)
                assert np.array_equal(
                    np.asarray(dense["X"]), np.asarray(res.fields["X"])
                )

    def test_unknown_schedule_rejected(self):
        g, _ = _setup("wcc")
        (s0, *_) = _steps(alg.WCC, g)
        with pytest.raises(ValueError):
            lower_step(s0, schedule="bogus")

    def test_one_op_is_one_superstep_across_stdlib(self):
        """`len(plan.ops)` must equal read_rounds + main + remote-update —
        the invariant the STM cost models and all executors count on."""
        for name, src in alg.ALL.items():
            g, fields = _setup(name if name in ("sssp", "chain4") else "wcc")
            if name == "mis":
                fields = {"P": jnp.zeros((g.n_vertices,), jnp.float32)}
            elif name == "bipartite_matching":
                fields = {"Side": jnp.zeros((g.n_vertices,), jnp.int32)}
            elif name == "kcore":
                fields = {"K": jnp.full((g.n_vertices,), 2, jnp.int32)}
            elif name == "chain4":
                fields = {"D": jnp.zeros((g.n_vertices,), jnp.int32)}
            for step in _steps(alg.ALL[name], g, fields):
                for sched in SCHEDULES:
                    plan = lower_step(step, schedule=sched)
                    assert plan.n_supersteps == (
                        plan.read_rounds
                        + 1
                        + (1 if plan.has_remote_update else 0)
                    ), (name, sched)


class TestAutoSelector:
    def test_auto_matches_cheaper_hand_picked_plan(self):
        """The selector's plan must be exactly the cheaper of the two
        hand-picked lowerings (by the plan's own op count; ties → pull)."""
        for name, src in alg.ALL.items():
            g = G.erdos_renyi(30, 3.0, directed=False, weighted=True, seed=1)
            fields = {
                "D": jnp.zeros((30,), jnp.int32),
                "P": jnp.zeros((30,), jnp.float32),
                "Side": jnp.zeros((30,), jnp.int32),
                "K": jnp.full((30,), 2, jnp.int32),
            }
            for step in _steps(src, g, fields):
                pull = lower_step(step, schedule="pull")
                naive = lower_step(step, schedule="naive")
                auto = lower_step(step, schedule="auto")
                best = (
                    pull
                    if pull.n_supersteps <= naive.n_supersteps
                    else naive
                )
                assert auto.ops == best.ops, (name, auto.describe())
                assert auto.schedule == best.schedule
                assert auto.requested == "auto"

    def test_auto_cost_model_lower_bounds(self):
        """STM: auto ≤ min(pull_staged, naive) on any trip vector."""
        from repro.core.parser import parse
        from repro.core.stm import superstep_report

        for name, src in alg.ALL.items():
            rep = superstep_report(parse(src))
            trips = {i: 3 for i in range(4)}
            assert rep["auto"].count(trips) <= rep["pull_staged"].count(trips)
            assert rep["auto"].count(trips) <= rep["naive"].count(trips)


MATRIX_ALGS = ["sssp", "wcc", "sv", "chain4"]


class TestExecutorScheduleMatrix:
    """Every (executor × schedule) cell bit-matches the fused dense
    executor, with identical plan-derived superstep counts. S=1 exercises
    the whole partitioned machinery in-process (the 8-device subprocess
    case below keeps one multi-shard representative)."""

    @pytest.mark.parametrize("name", MATRIX_ALGS)
    @pytest.mark.parametrize("schedule", ["naive", "auto"])
    def test_partitioned_matches_dense(self, name, schedule):
        g, fields = _setup(name)
        cp = compile_program(alg.ALL[name], g, initial_fields=fields)
        dense, _, counts = cp.run(fields)
        f0 = cp.init_fields(fields)
        res = run_bsp(
            cp.prog, g, f0, schedule=schedule,
            placement="partitioned", n_shards=1,
        )
        for f in dense:
            assert np.array_equal(
                np.asarray(dense[f]), np.asarray(res.fields[f]),
                equal_nan=True,
            ), (name, schedule, f)
        assert res.supersteps == counts[schedule]

    @pytest.mark.parametrize("name", MATRIX_ALGS)
    def test_staged_and_partitioned_counts_agree(self, name):
        """Both executors charge the same plan, so their executed superstep
        totals agree cell-for-cell across schedules."""
        g, fields = _setup(name)
        cp = compile_program(alg.ALL[name], g, initial_fields=fields)
        f0 = cp.init_fields(fields)
        for schedule in ("pull", "naive", "auto"):
            staged = run_bsp(cp.prog, g, f0, schedule=schedule)
            part = run_bsp(
                cp.prog, g, f0, schedule=schedule,
                placement="partitioned", n_shards=1,
            )
            assert staged.supersteps == part.supersteps, (name, schedule)

    def test_fused_dense_naive_schedule_matches_pull(self):
        """compile_program(schedule="naive") folds the request/reply plan
        into the fused trace; results are bit-identical to pull (the wire
        term is exactly zero)."""
        for name in MATRIX_ALGS:
            g, fields = _setup(name)
            ref, _, _ = compile_program(
                alg.ALL[name], g, initial_fields=fields
            ).run(fields)
            out, _, _ = compile_program(
                alg.ALL[name], g, initial_fields=fields, schedule="naive"
            ).run(fields)
            for f in ref:
                assert np.array_equal(
                    np.asarray(ref[f]), np.asarray(out[f]), equal_nan=True
                ), (name, f)


class TestChainModeShim:
    def test_chain_mode_global_still_honored_with_warning(self):
        g, fields = _setup("chain4")
        ref = compile_program(
            alg.CHAIN4, g, initial_fields=fields, schedule="naive"
        )
        ref_out, _, _ = ref.run(fields)
        old = codegen.CHAIN_MODE
        try:
            codegen.CHAIN_MODE = "naive"
            cp = compile_program(alg.CHAIN4, g, initial_fields=fields)
            with pytest.warns(DeprecationWarning):
                out, _, _ = cp.run(fields)
        finally:
            codegen.CHAIN_MODE = old
        assert np.array_equal(np.asarray(out["D4"]), np.asarray(ref_out["D4"]))

    def test_explicit_schedule_bypasses_global(self):
        g, fields = _setup("chain4")
        old = codegen.CHAIN_MODE
        try:
            codegen.CHAIN_MODE = "naive"
            import warnings

            with warnings.catch_warnings():
                warnings.simplefilter("error", DeprecationWarning)
                cp = compile_program(
                    alg.CHAIN4, g, initial_fields=fields, schedule="pull"
                )
                cp.run(fields)
        finally:
            codegen.CHAIN_MODE = old


SUBPROCESS_TEST = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import algorithms as alg, compile_program
    from repro.graph import generators as G
    from repro.pregel import run_bsp

    # one representative program: S-V has chain access (pointer doubling vs
    # per-hop gather_global), neighborhood reads, and remote writes — every
    # collective the naive partitioned path adds
    g = G.erdos_renyi(48, 3.0, directed=False, weighted=True, seed=3)
    cp = compile_program(alg.SV, g)
    dense, _, counts = cp.run()
    f0 = cp.init_fields()
    for sched, key in (("naive", "naive"), ("auto", "auto")):
        res = run_bsp(cp.prog, g, f0, schedule=sched, placement="partitioned")
        for f in dense:
            a, b = np.asarray(dense[f]), np.asarray(res.fields[f])
            assert np.array_equal(a, b, equal_nan=True), (sched, f)
        assert res.supersteps == counts[key], (
            sched, res.supersteps, counts[key])
        print(sched, "ok", res.supersteps)
    print("PLAN_SUBPROCESS_OK")
    """
)


@pytest.mark.subprocess_mesh
def test_partitioned_naive_multidevice_single_program():
    """S-V under schedule="naive"/"auto" on the 8-fake-device mesh:
    bit-identical fields and plan-derived superstep counts vs dense."""
    res = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_TEST],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        timeout=900,
        cwd=str(Path(__file__).resolve().parent.parent),
    )
    assert "PLAN_SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr
