"""Superstep-plan IR tests (`repro.core.plan`).

Four layers:

* plan structure: op lists under pull/push/naive, one op == one superstep
  (`len(plan.ops)` is the accounting contract), chain4's known shapes
  (pull pointer doubling, the paper's 3-round push derivation, naive's
  six request/reply rounds);
* the ``auto`` selector: per step, its plan must equal the cheapest of
  the hand-picked pull/push/naive plans (ties pull → push → naive), and
  with a :class:`~repro.core.plan.ByteCostModel` the byte-aware metric
  must flip it to push/naive on tiny request sets at deep chains;
* the (executor × schedule) matrix in-process: partitioned(S=1) push,
  naive and auto bit-match the fused dense executor with identical
  plan-derived superstep counts — every schedule now executable on every
  executor.

One 8-fake-device subprocess case (a single representative program, see
the ``subprocess_mesh`` marker) keeps the multi-shard push/naive
collectives honest without re-paying the full subprocess matrix.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import ast as past
from repro.core import ByteCostModel, compile_program, lower_step
from repro.core.analysis import analyze_step, iter_steps
from repro.core.plan import (
    MainCompute,
    ReadRound,
    RemoteUpdate,
    SCHEDULES,
    plan_score,
)
from repro.graph import generators as G
from repro.pregel import run_bsp


def _steps(src, g, fields=None):
    cp = compile_program(src, g, initial_fields=fields)
    return [s for s in iter_steps(cp.prog) if isinstance(s, past.Step)]


def _setup(name, seed=3):
    fields = None
    if name == "sssp":
        g = G.erdos_renyi(40, 4.0, directed=True, weighted=True, seed=seed)
    elif name == "chain4":
        g = G.erdos_renyi(30, 2.0, directed=False, seed=seed)
        rng = np.random.default_rng(seed)
        fields = {"D": jnp.asarray(rng.integers(0, 30, 30), jnp.int32)}
    else:
        g = G.erdos_renyi(40, 3.0, directed=False, weighted=True, seed=seed)
    return g, fields


def _stdlib_fields(name, g, fields):
    """Initial fields the stdlib programs need for compilation."""
    n = g.n_vertices
    if name == "mis":
        return {"P": jnp.zeros((n,), jnp.float32)}
    if name == "bipartite_matching":
        return {"Side": jnp.zeros((n,), jnp.int32)}
    if name == "kcore":
        return {"K": jnp.full((n,), 2, jnp.int32)}
    if name == "chain4":
        return {"D": jnp.zeros((n,), jnp.int32)}
    return fields


class TestPlanStructure:
    def test_chain4_pull_is_pointer_doubling(self):
        g, fields = _setup("chain4")
        (step,) = _steps(alg.CHAIN4, g, fields)
        plan = lower_step(step, schedule="pull")
        kinds = [type(op).__name__ for op in plan.ops]
        assert kinds == ["ReadRound", "ReadRound", "MainCompute"]
        # round 1 materializes D², round 2 composes D⁴ = D²∘D²
        assert plan.ops[0].chains[0].pattern == ("D", "D")
        assert plan.ops[1].chains[0].pattern == ("D",) * 4
        assert plan.ops[1].chains[0].prefix == ("D", "D")
        assert plan.ops[1].chains[0].suffix == ("D", "D")
        assert plan.read_rounds == 2 and plan.n_supersteps == 3

    def test_chain4_naive_is_request_reply_per_hop(self):
        g, fields = _setup("chain4")
        (step,) = _steps(alg.CHAIN4, g, fields)
        plan = lower_step(step, schedule="naive")
        rr = [op for op in plan.ops if isinstance(op, ReadRound)]
        # three hops (D², D³, D⁴), each a request+reply pair
        assert [op.kind for op in rr] == ["request", "reply"] * 3
        # each naive hop splits off the last field
        for op in rr:
            (ce,) = op.chains
            assert ce.prefix == ce.pattern[:-1] and ce.suffix == (ce.pattern[-1],)
        assert plan.n_supersteps == 7  # 6 read rounds + main (paper: naive)

    def test_chain4_push_is_paper_three_round_derivation(self):
        """The executable push plan reproduces the paper's §4.1.1 result:
        D⁴ in 3 message rounds (request forward, D² combined reply +
        request forward, D⁴ combined reply) — half of naive's six."""
        g, fields = _setup("chain4")
        (step,) = _steps(alg.CHAIN4, g, fields)
        plan = lower_step(step, schedule="push")
        rr = [op for op in plan.ops if isinstance(op, ReadRound)]
        assert [op.kind for op in rr] == [
            "push_request", "push_reply", "push_reply",
        ]
        # round 1 carries the address flow only; round 2 materializes D²
        # (and forwards the request to D²[u]); round 3 composes D⁴ = D²∘D²
        assert rr[0].chains == () and rr[0].sends
        assert rr[1].chains[0].pattern == ("D", "D") and rr[1].sends
        assert rr[2].chains[0].pattern == ("D",) * 4
        assert rr[2].chains[0].prefix == ("D", "D")
        assert rr[2].chains[0].suffix == ("D", "D")
        # every push round carries the message-combining op
        assert all(op.combiner == "min" for op in rr)
        # plan rounds == the PushSolver's minimal count the STM charges
        assert plan.read_rounds == analyze_step(step).push_read_rounds() == 3
        assert plan.n_supersteps == 4  # paper: 3 rounds + main

    def test_push_rounds_match_solver_across_stdlib(self):
        """The executable push plan charges exactly the PushSolver-minimal
        read rounds the paper-faithful STM (`palgol_push`) counts — the
        re-alignment contract: accounting == dispatch, now for push too."""
        for name in alg.ALL:
            g, fields = _setup(name if name in ("sssp", "chain4") else "wcc")
            fields = _stdlib_fields(name, g, fields)
            for step in _steps(alg.ALL[name], g, fields):
                plan = lower_step(step, schedule="push")
                assert plan.read_rounds == analyze_step(
                    step
                ).push_read_rounds(), name

    def test_remote_update_carries_write_descs(self):
        g, _ = _setup("sv")
        steps = _steps(alg.SV, g)
        body = steps[-1]  # the iteration body step (has the remote write)
        plan = lower_step(body, schedule="pull")
        (ru,) = [op for op in plan.ops if isinstance(op, RemoteUpdate)]
        assert ru.writes == (("D", "<?="),)
        assert plan.ops[-2] == MainCompute(emits_remote=True)

    def test_general_read_costs_read_rounds(self):
        """A computed-index ("general") read is one request/reply
        conversation in manual code and one gather round under pull; the
        plan charges those supersteps (chain-less rounds — the value is
        consumed inline in main), keeping the old STM charges AND making
        every executor actually dispatch what the model counts."""
        src = """
for v in V
    local A[v] := Id[v] % numV
    local B[v] := Id[v] * 2
end
for v in V
    local X[v] := B[(A[v] + 1) % numV]
end
"""
        g = G.erdos_renyi(24, 2.0, directed=False, seed=0)
        cp = compile_program(src, g)
        step = _steps(src, g)[-1]
        pull = lower_step(step, schedule="pull")
        naive = lower_step(step, schedule="naive")
        assert pull.read_rounds == 1 and not pull.ops[0].chains
        assert [op.kind for op in naive.ops[:-1]] == ["request", "reply"]
        # old STM charges hold and match execution on every executor
        dense, _, counts = cp.run()
        assert counts["pull_staged"] == 1 + 2  # init main + RR + main
        assert counts["naive"] == 1 + 3
        f0 = cp.init_fields()
        for sched in ("pull", "push", "naive", "auto"):
            for placement, kw in (
                ("replicated", {}), ("partitioned", {"n_shards": 1}),
            ):
                # fuse=False isolates the per-step expansion this test pins
                res = run_bsp(
                    cp.prog, g, f0, schedule=sched, placement=placement,
                    fuse=False, **kw
                )
                key = {
                    "pull": "pull_staged", "auto": "pull_staged",
                    "push": "push", "naive": "naive",
                }[sched]
                assert res.supersteps == counts[key], (sched, placement)
                assert np.array_equal(
                    np.asarray(dense["X"]), np.asarray(res.fields["X"])
                )

    def test_unknown_schedule_rejected(self):
        g, _ = _setup("wcc")
        (s0, *_) = _steps(alg.WCC, g)
        with pytest.raises(ValueError):
            lower_step(s0, schedule="bogus")

    def test_one_op_is_one_superstep_across_stdlib(self):
        """`len(plan.ops)` must equal read_rounds + main + remote-update —
        the invariant the STM cost models and all executors count on."""
        for name, src in alg.ALL.items():
            g, fields = _setup(name if name in ("sssp", "chain4") else "wcc")
            fields = _stdlib_fields(name, g, fields)
            for step in _steps(alg.ALL[name], g, fields):
                for sched in SCHEDULES:
                    plan = lower_step(step, schedule=sched)
                    assert plan.n_supersteps == (
                        plan.read_rounds
                        + 1
                        + (1 if plan.has_remote_update else 0)
                    ), (name, sched)


class TestAutoSelector:
    def test_auto_matches_cheapest_hand_picked_plan(self):
        """The selector's plan must be exactly the cheapest of the three
        hand-picked lowerings (by the plan's own op count; ties keep the
        pull → push → naive preference order)."""
        for name, src in alg.ALL.items():
            g = G.erdos_renyi(30, 3.0, directed=False, weighted=True, seed=1)
            fields = {
                "D": jnp.zeros((30,), jnp.int32),
                "P": jnp.zeros((30,), jnp.float32),
                "Side": jnp.zeros((30,), jnp.int32),
                "K": jnp.full((30,), 2, jnp.int32),
            }
            for step in _steps(src, g, fields):
                hand = [
                    lower_step(step, schedule=s)
                    for s in ("pull", "push", "naive")
                ]
                auto = lower_step(step, schedule="auto")
                best = min(hand, key=lambda p: p.n_supersteps)
                assert auto.ops == best.ops, (name, auto.describe())
                assert auto.schedule == best.schedule
                assert auto.requested == "auto"

    def test_auto_cost_model_lower_bounds(self):
        """STM: auto ≤ min(pull_staged, push, naive) on any trip vector."""
        from repro.core.parser import parse
        from repro.core.stm import superstep_report

        for name, src in alg.ALL.items():
            rep = superstep_report(parse(src))
            trips = {i: 3 for i in range(4)}
            assert rep["auto"].count(trips) <= rep["pull_staged"].count(trips)
            assert rep["auto"].count(trips) <= rep["push"].count(trips)
            assert rep["auto"].count(trips) <= rep["naive"].count(trips)

    def test_byte_aware_auto_never_costlier_and_flips_on_sparse(self):
        """With a ByteCostModel, auto's score must lower-bound every
        hand-picked schedule's score; on a deep chain with a tiny
        (combined) request set it must abandon pull — pointer doubling
        materializes intermediates at *every* vertex, so per-hop
        request/reply wins the byte model there (ROADMAP's 'naive can win
        on tiny request sets at deep chains', now selected for real)."""
        g, fields = _setup("chain4")
        (step,) = _steps(alg.CHAIN4, g, fields)
        dense = ByteCostModel(n_vertices=g.n_vertices)
        sparse = ByteCostModel(
            n_vertices=g.n_vertices, request_set=4, combined_request_set=2
        )
        for costs in (dense, sparse):
            auto = lower_step(step, schedule="auto", byte_costs=costs)
            for s in ("pull", "push", "naive"):
                hand = lower_step(step, schedule=s)
                assert plan_score(auto, costs) <= plan_score(hand, costs), s
        assert lower_step(step, schedule="auto", byte_costs=dense).schedule == "pull"
        picked = lower_step(step, schedule="auto", byte_costs=sparse)
        assert picked.schedule in ("push", "naive")
        # message combining makes push the winner of the sparse regime
        assert picked.schedule == "push"

    def test_byte_aware_auto_matches_execution_and_stm(self):
        """run_bsp(schedule="auto", byte_costs=...) must execute exactly
        the superstep count the STM auto model (built with the same costs)
        predicts, and still bit-match dense — on both placements."""
        g, fields = _setup("chain4")
        sparse = ByteCostModel(
            n_vertices=g.n_vertices, request_set=4, combined_request_set=2
        )
        cp = compile_program(
            alg.CHAIN4, g, initial_fields=fields, byte_costs=sparse
        )
        dense_out, _, counts = cp.run(fields)
        # the auto model selected push for the one step of chain4
        assert counts["auto"] == counts["push"] > counts["pull_staged"]
        f0 = cp.init_fields(fields)
        for placement, kw in (
            ("replicated", {}), ("partitioned", {"n_shards": 1}),
        ):
            for fuse_flag, key in ((True, "fused_auto"), (False, "auto")):
                res = run_bsp(
                    cp.prog, g, f0, schedule="auto", placement=placement,
                    byte_costs=sparse, fuse=fuse_flag, **kw,
                )
                assert res.supersteps == counts[key], (placement, fuse_flag)
                assert np.array_equal(
                    np.asarray(dense_out["D4"]), np.asarray(res.fields["D4"])
                )


MATRIX_ALGS = ["sssp", "wcc", "sv", "chain4"]


class TestExecutorScheduleMatrix:
    """Every (executor × schedule) cell bit-matches the fused dense
    executor, with identical plan-derived superstep counts. S=1 exercises
    the whole partitioned machinery in-process (the 8-device subprocess
    case below keeps one multi-shard representative)."""

    #: schedule → (fused, unfused) STM cost-model keys
    SCHED_COUNTS = {
        "pull": ("palgol_pull", "pull_staged"),
        "push": ("palgol_push", "push"),
        "naive": ("fused_naive", "naive"),
        "auto": ("fused_auto", "auto"),
    }

    @pytest.mark.parametrize("name", MATRIX_ALGS)
    @pytest.mark.parametrize("schedule", ["push", "naive", "auto"])
    def test_partitioned_matches_dense(self, name, schedule):
        g, fields = _setup(name)
        cp = compile_program(alg.ALL[name], g, initial_fields=fields)
        dense, _, counts = cp.run(fields)
        f0 = cp.init_fields(fields)
        res = run_bsp(
            cp.prog, g, f0, schedule=schedule,
            placement="partitioned", n_shards=1,
        )
        for f in dense:
            assert np.array_equal(
                np.asarray(dense[f]), np.asarray(res.fields[f]),
                equal_nan=True,
            ), (name, schedule, f)
        # the default execution is the fused plan
        assert res.supersteps == counts[self.SCHED_COUNTS[schedule][0]]

    @pytest.mark.parametrize("name", MATRIX_ALGS)
    def test_staged_and_partitioned_counts_agree(self, name):
        """Both executors charge the same plan, so their executed superstep
        totals agree cell-for-cell across schedules."""
        g, fields = _setup(name)
        cp = compile_program(alg.ALL[name], g, initial_fields=fields)
        f0 = cp.init_fields(fields)
        for schedule in ("pull", "push", "naive", "auto"):
            staged = run_bsp(cp.prog, g, f0, schedule=schedule)
            part = run_bsp(
                cp.prog, g, f0, schedule=schedule,
                placement="partitioned", n_shards=1,
            )
            assert staged.supersteps == part.supersteps, (name, schedule)

    @pytest.mark.parametrize("schedule", ["push", "naive"])
    def test_fused_dense_schedule_matches_pull(self, schedule):
        """compile_program(schedule=...) folds the request/reply (or
        request/combined-reply) plan into the fused trace; results are
        bit-identical to pull (the wire term is exactly zero)."""
        for name in MATRIX_ALGS:
            g, fields = _setup(name)
            ref, _, _ = compile_program(
                alg.ALL[name], g, initial_fields=fields
            ).run(fields)
            out, _, _ = compile_program(
                alg.ALL[name], g, initial_fields=fields, schedule=schedule
            ).run(fields)
            for f in ref:
                assert np.array_equal(
                    np.asarray(ref[f]), np.asarray(out[f]), equal_nan=True
                ), (name, f)

    def test_push_executed_counts_match_both_fuse_settings(self):
        """Executed push supersteps == the `push` STM total when unfused,
        and == the paper-faithful `palgol_push` total (state merging +
        iteration fusion) by default — optimized accounting IS optimized
        execution now, not a separate model."""
        for name in MATRIX_ALGS:
            g, fields = _setup(name)
            cp = compile_program(alg.ALL[name], g, initial_fields=fields)
            _, _, counts = cp.run(fields)
            f0 = cp.init_fields(fields)
            res = run_bsp(cp.prog, g, f0, schedule="push", fuse=False)
            assert res.supersteps == counts["push"], name
            fused = run_bsp(cp.prog, g, f0, schedule="push")
            assert fused.supersteps == counts["palgol_push"], name
            assert counts["palgol_push"] <= counts["push"], name


def test_chain_mode_shim_removed():
    """PR 3's one-release deprecation window is over: the mutable
    ``codegen.CHAIN_MODE`` global must be gone for good."""
    from repro.core import codegen

    assert not hasattr(codegen, "CHAIN_MODE")
    assert not hasattr(codegen, "resolve_schedule")


SUBPROCESS_TEST = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import algorithms as alg, compile_program
    from repro.graph import generators as G
    from repro.pregel import run_bsp

    # one representative program: S-V has chain access (pointer doubling vs
    # per-hop gather_global vs the push request/combined-reply rounds),
    # neighborhood reads, and remote writes — every collective the
    # push/naive partitioned paths add
    g = G.erdos_renyi(48, 3.0, directed=False, weighted=True, seed=3)
    cp = compile_program(alg.SV, g)
    dense, _, counts = cp.run()
    f0 = cp.init_fields()
    for sched, key in (
        ("push", "palgol_push"), ("naive", "fused_naive"),
        ("auto", "fused_auto"),
    ):
        res = run_bsp(cp.prog, g, f0, schedule=sched, placement="partitioned")
        for f in dense:
            a, b = np.asarray(dense[f]), np.asarray(res.fields[f])
            assert np.array_equal(a, b, equal_nan=True), (sched, f)
        assert res.supersteps == counts[key], (
            sched, res.supersteps, counts[key])
        print(sched, "ok", res.supersteps)
    print("PLAN_SUBPROCESS_OK")
    """
)


@pytest.mark.subprocess_mesh
def test_partitioned_schedules_multidevice_single_program():
    """S-V under schedule="push"/"naive"/"auto" on the 8-fake-device mesh:
    bit-identical fields and plan-derived superstep counts vs dense."""
    res = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_TEST],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        timeout=900,
        cwd=str(Path(__file__).resolve().parent.parent),
    )
    assert "PLAN_SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr
