"""Property tests for the executable push schedule + the byte-aware
``auto`` selector (hypothesis-stub compatible: on hermetic images the
``repro.testing.hypothesis_stub`` shim runs these as seeded random tests).

Invariants encoded:

* **push round structure** — for any randomized set of chain reads, the
  plan's push read rounds equal the PushSolver-minimal count the
  paper-faithful STM charges (``analyze_step(...).push_read_rounds()``),
  each round is one of the two push kinds carrying the combining op, and
  every request/reply *conversation* costs exactly ``2·hops`` supersteps:
  naive charges ``2·hops`` for ``hops = Σ (len(p)−1) + general reads``,
  a single-hop chain costs push exactly 2 (its one request + one combined
  reply), and deeper chains cost push at most ``2·hops`` (address flows
  overlap value flows — the paper's D⁴-in-3-rounds headline);
* **byte-aware auto never loses** — for randomized byte-cost models, the
  plan ``auto`` selects is never costlier than *both* pull and naive (nor
  push) under :func:`repro.core.plan.plan_score`.
"""

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ast
from repro.core.analysis import analyze_step
from repro.core.plan import (
    ByteCostModel,
    ReadRound,
    lower_step,
    plan_score,
)

CHAIN_FIELDS = ["D", "E"]


def _chain_expr(pat):
    e = ast.Var("v")
    for f in pat:
        e = ast.FieldAccess(f, e)
    return e


def _step_reading(pats):
    """A synthetic step whose remote reads are exactly ``pats``."""
    body = tuple(
        ast.LocalWrite(f"X{i}", ":=", _chain_expr(p))
        for i, p in enumerate(pats)
    )
    return ast.Step("v", body)


@st.composite
def chain_patterns(draw):
    n = draw(st.integers(1, 3))
    pats = []
    for _ in range(n):
        k = draw(st.integers(2, 6))
        pats.append(
            tuple(draw(st.sampled_from(CHAIN_FIELDS)) for _ in range(k))
        )
    return pats


@settings(max_examples=40, deadline=None)
@given(chain_patterns())
def test_push_rounds_minimal_and_conversations_cost_two(pats):
    step = _step_reading(pats)
    info = analyze_step(step)
    push = lower_step(step, schedule="push")
    naive = lower_step(step, schedule="naive")
    # the executable plan charges exactly what the paper-faithful STM
    # counts (the re-alignment contract), via the two push round kinds
    assert push.read_rounds == info.push_read_rounds()
    for op in push.ops:
        if isinstance(op, ReadRound):
            assert op.kind in ("push_request", "push_reply")
            assert op.combiner == "min"
    # naive: every hop is one request + one reply — exactly 2·hops
    hops = sum(len(p) - 1 for p in info.read_patterns())
    assert naive.read_rounds == 2 * hops
    # push overlaps address and value flows: never more than naive,
    # and exactly 2·hops for a single-hop conversation
    assert push.read_rounds <= 2 * hops
    if len(pats) == 1 and len(pats[0]) == 2:
        assert push.read_rounds == 2
    # every schedule materializes the same requested patterns
    for p in info.read_patterns():
        assert p in push.materialized


@settings(max_examples=40, deadline=None)
@given(
    chain_patterns(),
    st.integers(1, 64),
    st.integers(1, 64),
    st.integers(0, 4096),
)
def test_byte_aware_auto_never_costlier_than_any_schedule(
    pats, request_set, combined, overhead
):
    step = _step_reading(pats)
    costs = ByteCostModel(
        n_vertices=64,
        request_set=request_set,
        combined_request_set=min(combined, request_set),
        superstep_overhead_bytes=overhead,
    )
    auto = lower_step(step, schedule="auto", byte_costs=costs)
    for sched in ("pull", "push", "naive"):
        hand = lower_step(step, schedule=sched)
        assert plan_score(auto, costs) <= plan_score(hand, costs), sched
    # and without costs the metric degrades to op count (ties → pull)
    bare = lower_step(step, schedule="auto")
    assert bare.n_supersteps == min(
        lower_step(step, schedule=s).n_supersteps
        for s in ("pull", "push", "naive")
    )
