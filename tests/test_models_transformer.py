"""Transformer model tests: attention equivalences, decode consistency, MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import MoEConfig, TransformerConfig
from repro.models.transformer import model as tm
from repro.models.transformer import attention as att
from repro.models.transformer import moe as moe_mod


def tiny_cfg(**kw):
    base = dict(
        name="tiny",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=101,
        qk_norm=True,
        qkv_bias=True,
        param_dtype="float32",
        compute_dtype="float32",
        attn_chunk_q=8,
        attn_chunk_kv=8,
    )
    base.update(kw)
    return TransformerConfig(**base)


class TestAttention:
    @pytest.mark.parametrize("window", [None, 8])
    @pytest.mark.parametrize("causal", [True, False])
    def test_chunked_matches_dense(self, window, causal):
        key = jax.random.PRNGKey(0)
        b, s, h, hkv, dh = 2, 33, 4, 2, 16
        q = jax.random.normal(key, (b, s, h, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, hkv, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, hkv, dh))
        pos = jnp.arange(s)
        dense = att.attention_dense(q, k, v, pos, pos, causal=causal, window=window)
        chunked = att.attention_chunked(
            q, k, v, pos, pos, causal=causal, window=window, chunk_q=8, chunk_kv=8
        )
        np.testing.assert_allclose(
            np.asarray(dense), np.asarray(chunked), rtol=2e-5, atol=2e-5
        )

    def test_rope_relative_shift_invariance(self):
        """RoPE scores depend only on relative positions."""
        key = jax.random.PRNGKey(3)
        q = jax.random.normal(key, (1, 4, 2, 32))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 2, 32))
        p0 = jnp.arange(4)
        q0 = att.apply_rope(q, p0, 1e4)
        k0 = att.apply_rope(k, p0, 1e4)
        q1 = att.apply_rope(q, p0 + 100, 1e4)
        k1 = att.apply_rope(k, p0 + 100, 1e4)
        s0 = jnp.einsum("bqhd,bkhd->bhqk", q0, k0)
        s1 = jnp.einsum("bqhd,bkhd->bhqk", q1, k1)
        np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=2e-4, atol=2e-4)

    def test_gqa_repeat(self):
        k = jnp.arange(2 * 3 * 2 * 4).reshape(2, 3, 2, 4).astype(jnp.float32)
        r = att.repeat_kv(k, 3)
        assert r.shape == (2, 3, 6, 4)
        np.testing.assert_array_equal(np.asarray(r[:, :, 0]), np.asarray(r[:, :, 2]))


class TestModel:
    def test_loss_near_log_vocab_at_init(self):
        cfg = tiny_cfg()
        params = tm.init(jax.random.PRNGKey(0), cfg)
        batch = {
            "tokens": jnp.ones((2, 16), jnp.int32),
            "labels": jnp.ones((2, 16), jnp.int32),
        }
        loss = tm.loss_fn(params, batch, cfg)
        assert abs(float(loss) - np.log(cfg.vocab_size)) < 0.5

    def test_grads_finite(self):
        cfg = tiny_cfg()
        params = tm.init(jax.random.PRNGKey(0), cfg)
        batch = {
            "tokens": jnp.ones((2, 16), jnp.int32),
            "labels": jnp.ones((2, 16), jnp.int32),
        }
        g = jax.grad(lambda p: tm.loss_fn(p, batch, cfg))(params)
        for leaf in jax.tree_util.tree_leaves(g):
            assert np.all(np.isfinite(np.asarray(leaf)))

    def test_greedy_decode_matches_teacher_forcing(self):
        cfg = tiny_cfg(swa_window=16)
        params = tm.init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 12), 0, 101)
        logits_pre, cache = tm.prefill(params, toks, cfg, capacity=32)
        cur = jnp.argmax(logits_pre[:, -1], -1)[:, None].astype(jnp.int32)
        outs = [cur]
        for _ in range(8):
            dl, cache = tm.decode_step(params, cache, cur, cfg)
            cur = jnp.argmax(dl, -1)[:, None].astype(jnp.int32)
            outs.append(cur)
        seq = jnp.concatenate([toks] + outs, 1)
        lf, _ = tm.prefill(params, seq[:, :-1], cfg, capacity=32)
        ref = jnp.argmax(lf[:, 11:], -1)
        assert bool(jnp.all(ref == seq[:, 12:]))

    def test_swa_ring_buffer_decode(self):
        cfg = tiny_cfg(swa_window=8, attn_impl="dense")
        params = tm.init(jax.random.PRNGKey(0), cfg)
        toks = jax.random.randint(jax.random.PRNGKey(3), (1, 20), 0, 101)
        _, cache = tm.prefill(params, toks, cfg)
        assert cache["k"].shape[2] == 8  # window-bounded cache
        one = jnp.ones((1, 1), jnp.int32)
        dl, _ = tm.decode_step(params, cache, one, cfg)
        lfull, _ = tm.prefill(params, jnp.concatenate([toks, one], 1), cfg)
        np.testing.assert_allclose(
            np.asarray(dl), np.asarray(lfull[:, -1]), rtol=1e-4, atol=1e-4
        )

    def test_param_count_matches_analytic(self):
        cfg = tiny_cfg(qkv_bias=False, qk_norm=False)
        params = tm.init(jax.random.PRNGKey(0), cfg)
        actual = sum(
            int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params)
        )
        assert actual == cfg.n_params()


class TestMoE:
    def cfg(self):
        return tiny_cfg(
            n_kv_heads=4,
            moe=MoEConfig(
                n_experts=8, top_k=2, d_ff_expert=32, n_shared_experts=1
            ),
        )

    def test_moe_loss_and_grads(self):
        cfg = self.cfg()
        params = tm.init(jax.random.PRNGKey(1), cfg)
        batch = {
            "tokens": jnp.ones((2, 16), jnp.int32),
            "labels": jnp.ones((2, 16), jnp.int32),
        }
        loss = tm.loss_fn(params, batch, cfg)
        assert np.isfinite(float(loss))
        g = jax.grad(lambda p: tm.loss_fn(p, batch, cfg))(params)
        for leaf in jax.tree_util.tree_leaves(g):
            assert np.all(np.isfinite(np.asarray(leaf)))

    def test_dispatch_positions_within_capacity(self):
        mcfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=8)
        idx = jax.random.randint(jax.random.PRNGKey(0), (64, 2), 0, 4)
        cap = moe_mod.capacity(64, mcfg)
        pos, keep = moe_mod.dispatch_indices(idx, 4, cap)
        pos, keep, idx = map(np.asarray, (pos, keep, idx))
        flat = idx.reshape(-1)
        # positions are unique within each expert among kept slots
        for e in range(4):
            ps = pos[(flat == e) & keep]
            assert len(ps) == len(set(ps.tolist()))
            assert (ps < cap).all()

    def test_moe_output_is_gate_weighted_expert_mix(self):
        """With capacity ≥ tokens, MoE must equal the dense per-token mix."""
        mcfg = MoEConfig(n_experts=4, top_k=2, d_ff_expert=16,
                         capacity_factor=8.0)
        d = 8
        params = moe_mod.init_moe_params(jax.random.PRNGKey(0), d, mcfg,
                                         jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (10, d))
        y, _ = moe_mod.moe_ffn(x, params, mcfg)
        # reference: run every expert densely, combine with the same gates
        eidx, gate, _ = moe_mod.route(x, params["router"], mcfg)
        ref = np.zeros((10, d), np.float32)
        for t in range(10):
            for j in range(mcfg.top_k):
                e = int(eidx[t, j])
                h = jax.nn.silu(x[t] @ params["w1"][e]) * (x[t] @ params["w3"][e])
                ref[t] += float(gate[t, j]) * np.asarray(h @ params["w2"][e])
        np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-4, atol=1e-5)
