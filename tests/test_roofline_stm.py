"""Roofline HLO parsing + STM merging/fusion unit tests."""

import pytest

from repro.core.parser import parse
from repro.core.stm import build_stm, superstep_report
from repro.roofline.analysis import (
    HW,
    collective_bytes_from_hlo,
    roofline_terms,
    shape_bytes,
)


class TestHloParsing:
    def test_shape_bytes(self):
        assert shape_bytes("f32[128,1024]") == 128 * 1024 * 4
        assert shape_bytes("bf16[2,3,4]") == 48
        assert shape_bytes("(f32[8], s32[8])") == 64
        assert shape_bytes("pred[16]") == 16
        assert shape_bytes("f32[]") == 4  # scalar

    def test_collective_accounting(self):
        hlo = """
  %x = f32[1024,256] parameter(0)
  %ag = f32[1024,1024] all-gather(%x), replica_groups=[64,4]<=[256]
  %ar = f32[1024,256] all-reduce(%x), replica_groups=[16,16]<=[256]
  %rs = f32[64,256] reduce-scatter(%x), replica_groups=[16,16]<=[256]
  %done = f32[1024,1024] all-gather-done(%ag)
"""
        out = collective_bytes_from_hlo(hlo, 256)
        # all-gather: output 4MB × 3/4
        assert out["all-gather"] == pytest.approx(1024 * 1024 * 4 * 0.75)
        # all-reduce: 2 × out × 15/16
        assert out["all-reduce"] == pytest.approx(
            2 * 1024 * 256 * 4 * 15 / 16
        )
        # reduce-scatter: out × (n-1)
        assert out["reduce-scatter"] == pytest.approx(64 * 256 * 4 * 15)
        # -done must NOT double count
        assert out["total"] == pytest.approx(
            out["all-gather"] + out["all-reduce"] + out["reduce-scatter"]
        )

    def test_roofline_terms(self):
        t = roofline_terms(
            flops_per_device=197e12,  # exactly 1 second of compute
            hbm_bytes_per_device=819e9,  # exactly 1 second of HBM
            collective_bytes_per_device=100e9,  # 2 seconds of ICI
            n_devices=256,
            hw=HW(),
            model_flops=197e12 * 256,  # perfectly useful
        )
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["memory_s"] == pytest.approx(1.0)
        assert t["collective_s"] == pytest.approx(2.0)
        assert t["bottleneck"] == "collective_s"
        assert t["useful_flops_ratio"] == pytest.approx(1.0)
        assert t["roofline_fraction"] == pytest.approx(0.5)  # 2s vs 1s ideal


SIMPLE = """
for v in V
    local A[v] := 0
end
for v in V
    local A[v] := A[v] + 1
end
"""

NBR_ITER = """
for v in V
    local A[v] := Id[v]
end
do
    for v in V
        let m = minimum [A[e.id] | e <- Nbr[v]]
        if (m < A[v])
            local A[v] := m
    end
until fix [A]
"""

CHAIN_RW = """
do
    for v in V
        if (A[A[v]] == A[v])
            remote A[A[v]] <?= Id[v]
    end
until fix [A]
"""


class TestStmOptimizations:
    def test_sequence_merging_saves_one(self):
        prog = parse(SIMPLE)
        _, opt = build_stm(prog, "push", optimize=True)
        _, naive = build_stm(prog, "naive", optimize=False)
        assert opt.base == naive.base - 1  # two MAIN states merged into one

    def test_iteration_fusion_removes_send_superstep(self):
        prog = parse(NBR_ITER)
        _, fused = build_stm(prog, "push", optimize=True)
        _, plain = build_stm(prog, "push", optimize=False)
        # body = [RR(send), MAIN]: fused per-iter = 1, unfused = 2
        assert fused.per_iter[0] == 1
        assert plain.per_iter[0] == 2

    def test_chain_and_remote_write_states(self):
        prog = parse(CHAIN_RW)
        _, push = build_stm(prog, "push", optimize=True)
        _, pull = build_stm(prog, "pull", optimize=True)
        _, naive = build_stm(prog, "naive", optimize=False)
        # push: D² chain = 2 RR + MAIN + RU, fused ⇒ 3/iter
        assert push.per_iter[0] == 3
        # pull: 1 RR + MAIN + RU, fused ⇒ 2/iter
        assert pull.per_iter[0] == 2
        # naive: 2 RR (request/reply) + MAIN + RU, unfused ⇒ 4/iter
        assert naive.per_iter[0] == 4

    def test_report_orderings_on_stdlib(self):
        from repro.core import algorithms as alg

        for name, src in alg.ALL.items():
            rep = superstep_report(parse(src))
            trips = {i: 3 for i in range(4)}
            assert (
                rep["palgol_pull"].count(trips)
                <= rep["palgol_push"].count(trips)
                <= rep["naive"].count(trips)
            ), name
