"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels execute in interpret mode on CPU (the TPU lowering is the
target; interpret mode runs the same kernel body + grid semantics).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.embedding_bag import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.gather_rows import gather_rows_pallas
from repro.kernels.gather_rows.ref import gather_rows_ref
from repro.kernels.segment_reduce import segment_sum_ell
from repro.kernels.segment_reduce.ref import segment_sum_ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _f32(x):
    return jnp.asarray(x, jnp.float32)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "b,h,hkv,sq,sk,d,causal,window",
        [
            (2, 4, 2, 64, 64, 32, True, None),
            (1, 2, 2, 48, 80, 16, True, 16),
            (2, 8, 4, 33, 57, 64, False, None),
            (1, 4, 1, 128, 128, 128, True, 32),
            (1, 1, 1, 8, 256, 64, True, None),
        ],
    )
    def test_matches_ref(self, dtype, b, h, hkv, sq, sk, d, causal, window):
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(jax.random.fold_in(key, 1), (b, h, sq, d), dtype)
        k = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, sk, d), dtype)
        v = jax.random.normal(jax.random.fold_in(key, 3), (b, hkv, sk, d), dtype)
        out = flash_attention(
            q, k, v, causal=causal, window=window,
            block_q=32, block_k=32, interpret=True,
        )
        # ref accumulated in f32 (the kernel accumulates in f32 scratch, so
        # it is *more* accurate than a bf16-accumulated reference)
        ref = attention_ref(_f32(q), _f32(k), _f32(v), causal=causal,
                            window=window)
        np.testing.assert_allclose(
            np.asarray(out, np.float32),
            np.asarray(ref, np.float32),
            **TOL[dtype],
        )

    @settings(max_examples=10, deadline=None)
    @given(
        sq=st.integers(1, 96),
        sk=st.integers(8, 96),
        blk=st.sampled_from([16, 32]),
        causal=st.booleans(),
    )
    def test_property_ragged_shapes(self, sq, sk, blk, causal):
        key = jax.random.PRNGKey(42)
        q = jax.random.normal(key, (1, 2, sq, 32))
        k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, sk, 32))
        v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, sk, 32))
        out = flash_attention(
            q, k, v, causal=causal, block_q=blk, block_k=blk, interpret=True
        )
        ref = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5
        )


class TestSegmentSumEll:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "e,n,d,nb,eb,cap",
        [
            (500, 100, 16, 32, 32, None),
            (2000, 300, 64, 64, 64, None),
            (1000, 50, 8, 16, 16, 64),  # forced spill path
            (64, 9, 128, 8, 8, None),
        ],
    )
    def test_matches_ref(self, dtype, e, n, d, nb, eb, cap):
        rng = np.random.default_rng(0)
        ids = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
        vals = jnp.asarray(rng.normal(size=(e, d))).astype(dtype)
        mask = jnp.asarray(rng.random(e) < 0.9)
        out = segment_sum_ell(
            vals, ids, n, mask=mask, nb=nb, eb=eb, budget_cap=cap,
            interpret=True,
        )
        ref = segment_sum_ref(_f32(vals), ids, n, mask=mask)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype],
        )

    @settings(max_examples=10, deadline=None)
    @given(
        e=st.integers(10, 400),
        n=st.integers(2, 64),
        seed=st.integers(0, 99),
    )
    def test_property_random_graphs(self, e, n, seed):
        rng = np.random.default_rng(seed)
        ids = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
        vals = jnp.asarray(rng.normal(size=(e, 16)).astype(np.float32))
        out = segment_sum_ell(vals, ids, n, nb=16, eb=16, interpret=True)
        ref = segment_sum_ref(vals, ids, n)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5
        )


class TestEmbeddingBag:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize(
        "v,d,b,h", [(100, 16, 8, 4), (1000, 64, 16, 1), (50, 128, 4, 10)]
    )
    def test_matches_ref(self, dtype, v, d, b, h):
        rng = np.random.default_rng(2)
        table = jnp.asarray(rng.normal(size=(v, d))).astype(dtype)
        idx = jnp.asarray(rng.integers(0, v, (b, h)).astype(np.int32))
        w = jnp.asarray(rng.normal(size=(b, h))).astype(dtype)
        mask = jnp.asarray(rng.random((b, h)) < 0.8)
        out = embedding_bag_pallas(table, idx, weights=w, mask=mask,
                                   interpret=True)
        ref = embedding_bag_ref(_f32(table), idx, _f32(w) * mask)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            **TOL[dtype],
        )


class TestGatherRows:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
    @pytest.mark.parametrize("v,d,n", [(64, 16, 32), (500, 100, 7)])
    def test_exact(self, dtype, v, d, n):
        rng = np.random.default_rng(3)
        table = jnp.asarray(rng.integers(-5, 5, (v, d))).astype(dtype)
        idx = jnp.asarray(rng.integers(0, v, n).astype(np.int32))
        out = gather_rows_pallas(table, idx, interpret=True)
        ref = gather_rows_ref(table, idx)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_chain_access_composition(self):
        """gather(gather) == pull-mode chain evaluation (logic.py, D²)."""
        rng = np.random.default_rng(4)
        n = 64
        D = jnp.asarray(rng.integers(0, n, n).astype(np.int32))
        table = jnp.asarray(rng.normal(size=(n, 128)).astype(np.float32))
        d2 = np.asarray(D)[np.asarray(D)]
        via_kernel = gather_rows_pallas(
            gather_rows_pallas(table, D, interpret=True), D, interpret=True
        )
        np.testing.assert_allclose(
            np.asarray(via_kernel), np.asarray(table)[d2], rtol=1e-6
        )
