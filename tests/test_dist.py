"""Distribution layer tests: sharding rules + multi-device semantics.

Multi-device checks run in a subprocess with 8 fake host devices (device
count is fixed at process start), validating that the sharded execution
paths (EP MoE dispatch, shard_map message passing) produce bit-identical
results to the single-device reference paths.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import numpy as np
import pytest

from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh


class TestMeshConstruction:
    def test_single_pod(self):
        # 512 fake devices not available in this process (1 device); the
        # spec functions are pure given a Mesh, so use a 1×1 mesh here and
        # validate the production shapes in the dry-run artifacts.
        m = make_mesh((1, 1), ("data", "model"))
        assert m.axis_names == ("data", "model")

    def test_production_mesh_shapes(self):
        # shape arithmetic only (construction requires 512 devices)
        assert (2, 16, 16) == (2, 16, 16)


class TestShardingRules:
    def setup_method(self):
        self.mesh = make_mesh((1, 1), ("data", "model"))

    def test_lm_param_specs(self):
        import jax.numpy as jnp

        leaf = jax.ShapeDtypeStruct((4, 512, 1024), jnp.bfloat16)
        spec = shd.lm_param_spec("layers/wq", leaf, self.mesh)
        # divisibility always holds on the 1×1 mesh
        assert spec == P(None, "data", "model")
        spec = shd.lm_param_spec("layers/wo", leaf, self.mesh)
        assert spec == P(None, "model", "data")
        embed = jax.ShapeDtypeStruct((32000, 512), jnp.bfloat16)
        assert shd.lm_param_spec("embed", embed, self.mesh) == P("model", "data")
        norm = jax.ShapeDtypeStruct((4, 512), jnp.bfloat16)
        assert shd.lm_param_spec("layers/ln1", norm, self.mesh) == P()

    def test_moe_param_specs(self):
        import jax.numpy as jnp

        w1 = jax.ShapeDtypeStruct((4, 8, 512, 128), jnp.bfloat16)
        assert shd.lm_param_spec("layers/moe/w1", w1, self.mesh) == P(
            None, "model", "data", None
        )

    def test_indivisible_dims_drop_axes(self):
        import jax.numpy as jnp

        mesh = make_mesh((1, 1), ("data", "model"))
        odd = jax.ShapeDtypeStruct((7, 13), jnp.float32)
        # on a size-1 mesh everything divides; simulate indivisibility via
        # the helper directly
        assert shd._maybe(("data", "model"), (7, 13), mesh) == P("data", "model")

    def test_constrain_noop_without_mesh(self):
        import jax.numpy as jnp

        shd.deactivate()
        x = jax.numpy.ones((4, 4))
        y = shd.constrain(x, (shd.BATCH, None))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


SUBPROCESS_TEST = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist import sharding as shd
    from repro.models.transformer.config import MoEConfig
    from repro.models.transformer import moe as moe_mod
    from repro.graph import ops as gops

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    # --- EP MoE dispatch == local reference -----------------------------
    mcfg = MoEConfig(n_experts=8, top_k=2, d_ff_expert=16, capacity_factor=8.0)
    params = moe_mod.init_moe_params(jax.random.PRNGKey(0), 32, mcfg,
                                     jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 32))
    y_ref, _ = moe_mod._moe_ffn_local(x, params, mcfg)
    shd.activate(mesh)
    with mesh:
        y_ep, _ = jax.jit(lambda x, p: moe_mod.moe_ffn(x, p, mcfg))(x, params)
        g = jax.jit(jax.grad(
            lambda p: jnp.sum(moe_mod.moe_ffn(x, p, mcfg)[0] ** 2)
        ))(params)
    shd.deactivate()
    assert float(jnp.max(jnp.abs(y_ep - y_ref))) < 1e-5, "EP mismatch"
    assert all(
        bool(jnp.all(jnp.isfinite(leaf))) for leaf in jax.tree.leaves(g)
    )

    # --- shard_map message passing == direct ops ------------------------
    rng = np.random.default_rng(0)
    n, e, d = 96, 256, 16
    src = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    xfeat = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    mask = jnp.asarray(rng.random(e) < 0.9)
    ref_g = gops.gather(xfeat, src)
    ref_s = gops.segment_reduce(ref_g, dst, n, "sum", mask=mask)
    ref_m = gops.segment_reduce(ref_g, dst, n, "max", mask=mask)
    shd.activate(mesh)
    with mesh:
        mp_g = jax.jit(lambda f, i: gops.mp_gather(f, i))(xfeat, src)
        mp_s = jax.jit(
            lambda v, s, m: gops.mp_segment_reduce(v, s, n, "sum", mask=m)
        )(ref_g, dst, mask)
        mp_m = jax.jit(
            lambda v, s, m: gops.mp_segment_reduce(v, s, n, "max", mask=m)
        )(ref_g, dst, mask)
        # max-aggregation must be differentiable across shards
        gmax = jax.jit(jax.grad(lambda v: jnp.sum(jnp.where(
            jnp.isfinite(gops.mp_segment_reduce(v, dst, n, "max", mask=mask)),
            gops.mp_segment_reduce(v, dst, n, "max", mask=mask), 0.0))))(ref_g)
    shd.deactivate()
    assert np.allclose(np.asarray(mp_g), np.asarray(ref_g)), "mp_gather"
    assert np.allclose(np.asarray(mp_s), np.asarray(ref_s), atol=1e-5), "mp_sum"
    assert np.allclose(np.asarray(mp_m), np.asarray(ref_m)), "mp_max"
    assert np.all(np.isfinite(np.asarray(gmax))), "mp_max grad"

    # --- odd edge count: mesh path pads, never silently falls back ------
    eo = 257  # 257 % 8 != 0
    srco = jnp.asarray(rng.integers(0, n, eo).astype(np.int32))
    dsto = jnp.asarray(rng.integers(0, n, eo).astype(np.int32))
    masko = jnp.asarray(rng.random(eo) < 0.9)
    ref_go = gops.gather(xfeat, srco)
    ref_so = gops.segment_reduce(ref_go, dsto, n, "sum", mask=masko)
    ref_sm = gops.edge_softmax(ref_go[:, 0], dsto, n, mask=masko)
    shd.activate(mesh)
    with mesh:
        mp_go = jax.jit(lambda f, i: gops.mp_gather(f, i))(xfeat, srco)
        mp_so = jax.jit(
            lambda v, s, m: gops.mp_segment_reduce(v, s, n, "sum", mask=m)
        )(ref_go, dsto, masko)
        mp_smo = jax.jit(
            lambda v, s, m: gops.mp_edge_softmax(v, s, n, mask=m)
        )(ref_go[:, 0], dsto, masko)
    shd.deactivate()
    assert mp_go.shape == ref_go.shape, "odd-E gather shape"
    assert np.allclose(np.asarray(mp_go), np.asarray(ref_go)), "odd-E gather"
    assert np.allclose(
        np.asarray(mp_so), np.asarray(ref_so), atol=1e-5), "odd-E segsum"
    assert np.allclose(
        np.asarray(mp_smo), np.asarray(ref_sm), atol=1e-6), "odd-E softmax"
    print("SUBPROCESS_OK")
    """
)


@pytest.mark.subprocess_mesh
def test_multidevice_semantics():
    """EP MoE + shard_map MP match single-device refs on an 8-device mesh."""
    res = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_TEST],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        timeout=900,
        cwd=str(Path(__file__).resolve().parent.parent),
    )
    assert "SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr
