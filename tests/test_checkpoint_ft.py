"""Checkpoint, elastic resharding, failure recovery, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    AsyncCheckpointer,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)
from repro.ft import FailureInjector, StragglerMonitor, TrainSupervisor
from repro.ft.failures import InjectedFailure
from repro.optim.grad_compress import (
    compress,
    compress_with_feedback,
    decompress,
    make_compressed_dp_grad_fn,
)


def small_state():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "opt": {"m": jnp.zeros((3, 4)), "step": jnp.asarray(7, jnp.int32)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        state = small_state()
        save_checkpoint(tmp_path, 3, state)
        restored, step, _ = restore_checkpoint(tmp_path, state)
        assert step == 3
        for a, b in zip(
            jax.tree_util.tree_leaves(state),
            jax.tree_util.tree_leaves(restored),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_latest_step_picks_newest_complete(self, tmp_path):
        state = small_state()
        save_checkpoint(tmp_path, 1, state)
        save_checkpoint(tmp_path, 5, state)
        # a torn write must be ignored
        os.makedirs(tmp_path / "step_00000009.tmp")
        assert latest_step(tmp_path) == 5

    def test_async_checkpointer(self, tmp_path):
        ck = AsyncCheckpointer(tmp_path, keep=2)
        state = small_state()
        for s in (1, 2, 3):
            ck.save(s, state)
        ck.wait()
        assert latest_step(tmp_path) == 3
        # gc kept only the last two
        assert not (tmp_path / "step_00000001").exists()

    def test_elastic_restore_across_mesh_shapes(self, tmp_path):
        """Save under one mesh, restore under a different one."""
        mesh1 = jax.make_mesh(
            (1,), ("data",),
            axis_types=(jax.sharding.AxisType.Auto,),
        )
        from jax.sharding import NamedSharding, PartitionSpec as P

        state = {
            "w": jax.device_put(
                jnp.arange(16.0).reshape(4, 4),
                NamedSharding(mesh1, P("data", None)),
            )
        }
        save_checkpoint(tmp_path, 1, state)
        restored, _, _ = restore_checkpoint(tmp_path, state, mesh=mesh1)
        np.testing.assert_array_equal(
            np.asarray(restored["w"]), np.asarray(state["w"])
        )
        # restore with no mesh (single process) also works — elasticity to 1
        restored2, _, _ = restore_checkpoint(tmp_path, state)
        np.testing.assert_array_equal(
            np.asarray(restored2["w"]), np.asarray(state["w"])
        )


class TestSupervisor:
    def _setup(self, tmp_path, fail_at=()):
        calls = {"n": 0}

        def step_fn(state, batch):
            calls["n"] += 1
            new = {
                "x": state["x"] + batch,
                "step": state["step"] + 1,
            }
            return new, {"loss": float(new["x"][0])}

        sup = TrainSupervisor(
            step_fn,
            batch_for_step=lambda i: jnp.ones((2,)) * (i + 1),
            ckpt_dir=str(tmp_path),
            ckpt_every=2,
            injector=FailureInjector(list(fail_at)),
        )
        init = {"x": jnp.zeros((2,)), "step": jnp.asarray(0)}
        return sup, init, calls

    def test_clean_run(self, tmp_path):
        sup, init, _ = self._setup(tmp_path)
        state, step, metrics = sup.run(init, 6)
        assert step == 6
        # Σ (i+1) for i in 0..5 = 21
        assert float(state["x"][0]) == 21.0

    def test_recovers_from_injected_failure(self, tmp_path):
        sup, init, _ = self._setup(tmp_path, fail_at=[3])
        state, step, _ = sup.run(init, 6)
        assert step == 6
        assert sup.retries == 1
        # deterministic replay ⇒ identical final state
        assert float(state["x"][0]) == 21.0

    def test_resume_from_checkpoint(self, tmp_path):
        sup, init, _ = self._setup(tmp_path)
        sup.run(init, 4)
        # new supervisor (fresh process) continues from step 4
        sup2, init2, calls2 = self._setup(tmp_path)
        state, step, _ = sup2.run(init2, 6)
        assert step == 6 and sup2.restarts == 1
        assert calls2["n"] == 2  # only steps 4,5 executed
        assert float(state["x"][0]) == 21.0

    def test_exhausted_retries_raise(self, tmp_path):
        sup, init, _ = self._setup(tmp_path, fail_at=[0])
        sup.max_retries = 0
        with pytest.raises(InjectedFailure):
            sup.run(init, 3)


class TestStraggler:
    def test_detection(self):
        mon = StragglerMonitor(factor=2.0, warmup=1)
        assert not mon.observe(0, 1.0)
        assert not mon.observe(1, 1.1)
        assert mon.observe(2, 5.0)  # 5x the EMA
        assert len(mon.events) == 1
        # EMA not poisoned by the straggler
        assert mon.ema < 1.5


class TestGradCompression:
    def test_roundtrip_error_bounded(self):
        g = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)))
        q, s = compress(g)
        back = decompress(q, s)
        assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_accumulates(self):
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.normal(size=(32,)) * 1e-3)
        res = jnp.zeros((32,))
        # tiny gradients vanish under coarse quantization, but EF recovers
        total = jnp.zeros((32,))
        for _ in range(50):
            q, s, res = compress_with_feedback(g, res)
            total = total + decompress(q, s)
        np.testing.assert_allclose(
            np.asarray(total / 50), np.asarray(g), rtol=0.3, atol=2e-4
        )

    def test_compressed_dp_matches_exact_mean(self):
        mesh = jax.make_mesh(
            (1,), ("data",), axis_types=(jax.sharding.AxisType.Auto,)
        )

        def loss_fn(p, b):
            return jnp.mean((b @ p["w"]) ** 2)

        params = {"w": jnp.asarray(np.random.default_rng(2).normal(size=(4, 3)),
                                   jnp.float32)}
        batch = jnp.asarray(
            np.random.default_rng(3).normal(size=(8, 4)), jnp.float32
        )
        residuals = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
        fn = make_compressed_dp_grad_fn(loss_fn, mesh)
        loss, grads, new_res = fn(params, batch, residuals)
        exact = jax.grad(loss_fn)(params, batch)
        # int8 quantization error is bounded by scale/2 = max|g|/254
        atol = float(jnp.max(jnp.abs(exact["w"]))) / 254 + 1e-6
        np.testing.assert_allclose(
            np.asarray(grads["w"]), np.asarray(exact["w"]), rtol=0.05,
            atol=atol,
        )
