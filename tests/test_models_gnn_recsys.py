"""GNN + recsys model tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import gnn_full_batch, gnn_minibatches, recsys_batches
from repro.graph import generators as G
from repro.models.gnn import GNNConfig
from repro.models.gnn import models as gm
from repro.models.recsys import AutoIntConfig, autoint
from repro.models.recsys.embedding import embedding_bag, embedding_bag_ragged
from repro.optim import AdamWConfig, adamw_init, adamw_update


VARIANTS = [
    ("sage", dict()),
    ("gat", dict(n_heads=4)),
    ("pna", dict()),
    ("graphcast", dict(task="regression", d_edge=16)),
]


@pytest.mark.parametrize("variant,kw", VARIANTS)
def test_gnn_forward_backward(variant, kw):
    task = kw.get("task", "node_class")
    cfg = GNNConfig(
        name=variant, variant=variant, n_layers=2, d_hidden=16, d_in=8,
        n_out=5, **kw,
    )
    params = gm.init(jax.random.PRNGKey(0), cfg)
    batch = gnn_full_batch(64, 4.0, 8, 5, seed=1, task=task, n_out=5)
    loss = jax.jit(lambda p, b: gm.loss_fn(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: gm.loss_fn(p, batch, cfg))(params)
    for leaf in jax.tree_util.tree_leaves(g):
        assert np.all(np.isfinite(np.asarray(leaf)))


def test_gnn_training_reduces_loss():
    cfg = GNNConfig(name="sage", variant="sage", n_layers=2, d_hidden=32,
                    d_in=8, n_out=4)
    params = gm.init(jax.random.PRNGKey(0), cfg)
    batch = gnn_full_batch(128, 6.0, 8, 4, seed=2)
    oc = AdamWConfig(lr=1e-2, weight_decay=0.0)
    st = adamw_init(params, oc)
    loss0 = float(gm.loss_fn(params, batch, cfg))

    @jax.jit
    def step(p, s):
        loss, g = jax.value_and_grad(lambda q: gm.loss_fn(q, batch, cfg))(p)
        p, s = adamw_update(g, s, p, oc)
        return p, s, loss

    for _ in range(60):
        params, st, loss = step(params, st)
    assert float(loss) < loss0 * 0.7


def test_sage_minibatch_pipeline():
    cfg = GNNConfig(name="sage", variant="sage", n_layers=2, d_hidden=16,
                    d_in=8, n_out=4, fanouts=(5, 3))
    params = gm.init(jax.random.PRNGKey(0), cfg)
    g = G.erdos_renyi(200, 6.0, seed=2)
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(200, 8)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, 4, 200).astype(np.int32))
    it = gnn_minibatches(g, feats, labels, 16, (5, 3), seed=3)
    for _ in range(2):
        batch = next(it)
        loss = gm.sage_minibatch_loss(params, batch, cfg)
        assert np.isfinite(float(loss))
    logits = gm.sage_minibatch_forward(params, batch, cfg)
    assert logits.shape == (16, 4)


def test_graph_class_disjoint_union():
    cfg = GNNConfig(name="pna", variant="pna", n_layers=2, d_hidden=16,
                    d_in=4, n_out=3, task="graph_class")
    params = gm.init(jax.random.PRNGKey(0), cfg)
    b, n, e = 8, 10, 20
    rng = np.random.default_rng(1)
    src = rng.integers(0, n, (b, e)) + (np.arange(b)[:, None] * n)
    dst = rng.integers(0, n, (b, e)) + (np.arange(b)[:, None] * n)
    batch = {
        "x": jnp.asarray(rng.normal(size=(b * n, 4)).astype(np.float32)),
        "src": jnp.asarray(src.reshape(-1).astype(np.int32)),
        "dst": jnp.asarray(dst.reshape(-1).astype(np.int32)),
        "emask": jnp.ones((b * e,), bool),
        "graph_id": jnp.repeat(jnp.arange(b), n),
        "labels": jnp.asarray(rng.integers(0, 3, b).astype(np.int32)),
    }
    loss = gm.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))


class TestEmbeddingBag:
    def test_fixed_width_modes(self):
        rng = np.random.default_rng(0)
        table = jnp.asarray(rng.normal(size=(50, 8)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, 50, (4, 3)).astype(np.int32))
        mask = jnp.asarray([[1, 1, 0], [1, 0, 0], [1, 1, 1], [0, 0, 0]], bool)
        t = np.asarray(table)
        i = np.asarray(idx)
        m = np.asarray(mask)
        s = np.asarray(embedding_bag(table, idx, mask=mask, mode="sum"))
        mean = np.asarray(embedding_bag(table, idx, mask=mask, mode="mean"))
        mx = np.asarray(embedding_bag(table, idx, mask=mask, mode="max"))
        for b in range(4):
            rows = t[i[b][m[b]]]
            np.testing.assert_allclose(
                s[b], rows.sum(0) if len(rows) else 0, rtol=1e-5, atol=1e-6
            )
            if len(rows):
                # atol for near-zero elements: f32 summation-order noise
                np.testing.assert_allclose(
                    mean[b], rows.mean(0), rtol=1e-5, atol=1e-6
                )
                np.testing.assert_allclose(mx[b], rows.max(0), rtol=1e-5)
            else:
                np.testing.assert_allclose(mx[b], 0.0)

    def test_ragged_matches_fixed(self):
        rng = np.random.default_rng(1)
        table = jnp.asarray(rng.normal(size=(30, 4)).astype(np.float32))
        idx = jnp.asarray(rng.integers(0, 30, (5, 2)).astype(np.int32))
        fixed = embedding_bag(table, idx, mode="sum")
        flat = idx.reshape(-1)
        bags = jnp.repeat(jnp.arange(5), 2)
        ragged = embedding_bag_ragged(table, flat, bags, 5, mode="sum")
        np.testing.assert_allclose(
            np.asarray(fixed), np.asarray(ragged), rtol=1e-6
        )

    def test_weighted(self):
        table = jnp.eye(4, dtype=jnp.float32)
        idx = jnp.asarray([[0, 1]], jnp.int32)
        w = jnp.asarray([[2.0, 3.0]])
        out = np.asarray(embedding_bag(table, idx, weights=w))
        np.testing.assert_allclose(out[0], [2.0, 3.0, 0, 0])


class TestAutoInt:
    def test_loss_near_log2(self):
        cfg = AutoIntConfig(name="a", vocab_per_field=500)
        params = autoint.init(jax.random.PRNGKey(0), cfg)
        batch = next(recsys_batches(32, cfg.n_fields, 500))
        loss = autoint.loss_fn(params, batch, cfg)
        assert abs(float(loss) - np.log(2)) < 0.2

    def test_training_reduces_loss(self):
        cfg = AutoIntConfig(
            name="a", vocab_per_field=100, mlp_dims=(64,), n_attn_layers=2
        )
        params = autoint.init(jax.random.PRNGKey(0), cfg)
        batch = next(recsys_batches(256, cfg.n_fields, 100, seed=7))
        oc = AdamWConfig(lr=1e-3, weight_decay=0.0)
        st = adamw_init(params, oc)
        loss0 = float(autoint.loss_fn(params, batch, cfg))

        @jax.jit
        def step(p, s):
            loss, g = jax.value_and_grad(
                lambda q: autoint.loss_fn(q, batch, cfg)
            )(p)
            p, s = adamw_update(g, s, p, oc)
            return p, s, loss

        for _ in range(50):
            params, st, loss = step(params, st)
        assert float(loss) < loss0

    def test_retrieval_topk(self):
        cfg = AutoIntConfig(name="a", vocab_per_field=100)
        params = autoint.init(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        batch = {
            "fields": jnp.asarray(
                rng.integers(0, 100, (2, cfg.n_fields)).astype(np.int32)
            ),
            "candidates": jnp.asarray(
                rng.normal(size=(1000, cfg.d_attn)).astype(np.float32)
            ),
        }
        scores, ids = autoint.retrieval_score(params, batch, cfg, top_k=7)
        assert scores.shape == (2, 7) and ids.shape == (2, 7)
        # scores must be the true top-k of the full score matrix
        q = autoint.query_embedding(params, batch, cfg)
        full = np.asarray(q @ batch["candidates"].T)
        np.testing.assert_allclose(
            np.asarray(scores), np.sort(full, axis=1)[:, ::-1][:, :7], rtol=1e-5
        )
