"""Program-plan fusion tests (`repro.core.plan.lower_program` + `fuse`).

Four layers:

* **fusion structure** — state merging fires at program-node boundaries
  (and chains), the read/write-set guard withholds it across a
  read-after-write boundary, iteration fusion duplicates the body's
  leading ReadRound into the preceding superstep and merges it into the
  body's tail;
* **former-STM equivalence** (hypothesis-stub compatible property): on
  randomized chain programs the fused plan's superstep totals equal the
  pre-refactor ``build_stm(..., optimize=True)`` accounting — the
  unconditional-merge + iteration-fusion logic this PR deleted from
  ``core/stm.py``, ported verbatim below as the reference;
* **fused execution** — ``fuse=True`` (the default) bit-matches
  ``fuse=False`` on SSSP/WCC/S-V/chain4 for every schedule on both
  placements, executes exactly the ``palgol_*``/``fused_*`` STM totals,
  and saves ≥ 1 superstep per iteration on S-V (the §4.3.2 claim,
  measured); per-iteration fixed-point frontiers are recorded;
* one 8-fake-device subprocess representative keeps the multi-shard fused
  collectives (merged RemoteUpdate + prefetched ReadRound in one
  dispatch, deduplicated gather_global requests) honest.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import algorithms as alg
from repro.core import ast as past
from repro.core import compile_program, fuse, lower_program
from repro.core.parser import parse
from repro.core.plan import (
    IterInit,
    MainCompute,
    PlanLoop,
    ReadRound,
    RemoteUpdate,
    StopOp,
    Superstep,
    lower_step,
)
from repro.core.stm import build_stm
from repro.graph import generators as G
from repro.pregel import run_bsp


# ---------------------------------------------------------------------------
# reference: the deleted pre-refactor STM accounting (unconditional state
# merging at sequence boundaries, iteration fusion when the body starts
# with a read state) — what `optimize=True` used to count


def _former_optimized_count(prog: past.Prog, mode: str, trips) -> int:
    iter_counter = [0]

    def step_states(step):
        out = []
        for op in lower_step(step, schedule=mode).ops:
            if isinstance(op, ReadRound):
                out.append("read")
            elif isinstance(op, MainCompute):
                out.append("main")
            else:
                out.append("update")
        return out

    def build(p):
        if isinstance(p, past.Step):
            return step_states(p)
        if isinstance(p, past.StopStep):
            return ["main"]
        if isinstance(p, past.Seq):
            out = []
            for sub in p.progs:
                states = build(sub)
                if (
                    out and states
                    and isinstance(out[-1], str) and isinstance(states[0], str)
                ):
                    states = states[1:]  # unconditional §4.3.1 merge
                out.extend(states)
            return out
        if isinstance(p, past.Iter):
            body = build(p.body)
            idx = iter_counter[0]
            iter_counter[0] += 1
            if (
                not any(isinstance(b, tuple) for b in body)
                and body and body[0] == "read"
            ):
                # §4.3.2: S1 duplicated into init, merged into S_n
                return ["main", ("loop", body[1:], idx)]
            return ["main", ("loop", body, idx)]
        raise TypeError(type(p))

    def count(items) -> int:
        total = 0
        for it in items:
            if isinstance(it, str):
                total += 1
            else:
                _, body, idx = it
                per_iter = sum(1 for b in body if isinstance(b, str))
                total += int(trips.get(idx, 0)) * per_iter
                total += count([b for b in body if isinstance(b, tuple)])
        return total

    return count(build(prog))


def _chain(depth: int, field: str = "D") -> str:
    e = "v"
    for _ in range(depth):
        e = f"{field}[{e}]"
    return e


@st.composite
def chain_programs(draw):
    """Random Seq-of-chain-steps programs (optionally loop-wrapped): each
    step writes a fresh field and reads only chains over ``D``, so the
    read/write-set guard is satisfied at every boundary — the regime where
    the new conditional merge must reproduce the old unconditional one."""
    n_steps = draw(st.integers(1, 4))
    steps = [
        f"for v in V\n    local X{i}[v] := "
        f"{_chain(draw(st.integers(2, 5)))}\nend"
        for i in range(n_steps)
    ]
    body = "\n".join(steps)
    trips = draw(st.integers(1, 4))
    if draw(st.booleans()):
        inner = textwrap.indent(body, "    ")
        return f"do\n{inner}\nuntil iter [{trips}]", {0: trips}
    return body, {}


@settings(max_examples=30, deadline=None)
@given(chain_programs())
def test_fused_totals_match_former_stm_on_chain_programs(case):
    src, trips = case
    prog = parse(src)
    for mode in ("pull", "push"):
        got = build_stm(prog, mode, optimize=True)[1].count(trips)
        want = _former_optimized_count(prog, mode, trips)
        assert got == want, (src, mode, got, want)


# ---------------------------------------------------------------------------
# fusion structure


def _flat_parts(items):
    out = []
    for it in items:
        if isinstance(it, Superstep):
            out.append(it)
        else:
            out.extend(_flat_parts(it.body))
    return out


class TestFusionStructure:
    def test_disjoint_mains_merge_unconditionally(self):
        """§4.3.1's canonical example: two adjacent local-compute steps
        collapse into one superstep (message independence — even though
        the second reads what the first wrote, the merged superstep
        sequences compute before sends)."""
        pp = fuse(lower_program(parse(
            "for v in V\n    local A[v] := 0\nend\n"
            "for v in V\n    local A[v] := A[v] + 1\nend"
        )))
        assert len(pp.items) == 1
        (ss,) = pp.items
        assert [type(r.op) for r in ss.parts] == [MainCompute, MainCompute]

    def test_raw_guard_withholds_merge_into_read_round(self):
        """A ReadRound whose gathers read fields the previous superstep
        writes does NOT merge — its outgoing request set must be derivable
        from pre-superstep state."""
        pp = fuse(lower_program(parse(
            "for v in V\n    local A[v] := Id[v]\nend\n"
            "for v in V\n    local B[v] := A[A[v]]\nend"
        )))
        # step1 Main stays alone; step2 [RR, Main] keeps its own supersteps
        assert [it.describe() for it in pp.items] == [
            "Main", "RR[pull]", "Main",
        ]
        # but with disjoint fields the same shape merges
        pp2 = fuse(lower_program(parse(
            "for v in V\n    local A[v] := Id[v]\nend\n"
            "for v in V\n    local B[v] := D[D[v]]\nend"
        )))
        assert [it.describe() for it in pp2.items] == ["Main+RR[pull]", "Main"]

    def test_iteration_fusion_prefetches_leading_read_round(self):
        """S-V: the body's leading ReadRound is duplicated into the merged
        init superstep and overlapped with the body tail's RemoteUpdate —
        one dispatch carries both collectives, one superstep per iteration
        saved."""
        pp = fuse(lower_program(parse(alg.SV)))
        init, loop = pp.items
        assert isinstance(loop, PlanLoop) and loop.fused
        # init = init-step Main + IterInit + prefetched RR
        assert [type(r.op) for r in init.parts] == [
            MainCompute, IterInit, ReadRound,
        ]
        assert [ss.describe() for ss in loop.body] == ["Main", "RU+RR[pull]"]

    def test_stop_merges_as_message_independent_target(self):
        """MWM: the stop superstep merges into the preceding main (it
        consumes no messages), and iteration fusion lands the prefetch on
        the merged tail."""
        pp = fuse(lower_program(parse(alg.MWM)))
        _, loop = pp.items
        assert loop.fused
        tail = loop.body[-1]
        kinds = [type(r.op) for r in tail.parts]
        assert kinds == [MainCompute, StopOp, ReadRound]

    def test_fused_counts_equal_execution_contract(self):
        """pp.cost() is what build_stm(optimize=True) reports — stm.py has
        no derivation of its own anymore."""
        for src in alg.ALL.values():
            prog = parse(src)
            for mode in ("pull", "push", "naive"):
                base, per_iter, _ = fuse(
                    lower_program(prog, schedule=mode)
                ).cost()
                cm = build_stm(prog, mode, optimize=True)[1]
                assert (base, per_iter) == (cm.base, cm.per_iter)

    def test_unfused_plan_counts_one_op_per_superstep(self):
        for src in alg.ALL.values():
            prog = parse(src)
            pp = lower_program(prog)
            for ss in _flat_parts(pp.items):
                assert len(ss.parts) == 1


# ---------------------------------------------------------------------------
# fused execution


def _setup(name, seed=3):
    fields = None
    if name == "sssp":
        g = G.erdos_renyi(40, 4.0, directed=True, weighted=True, seed=seed)
    elif name == "chain4":
        g = G.erdos_renyi(30, 2.0, directed=False, seed=seed)
        rng = np.random.default_rng(seed)
        fields = {"D": jnp.asarray(rng.integers(0, 30, 30), jnp.int32)}
    else:
        g = G.erdos_renyi(40, 3.0, directed=False, weighted=True, seed=seed)
    return g, fields


FUSED_KEY = {
    "pull": "palgol_pull", "push": "palgol_push",
    "naive": "fused_naive", "auto": "fused_auto",
}
UNFUSED_KEY = {
    "pull": "pull_staged", "push": "push", "naive": "naive", "auto": "auto",
}


class TestFusedExecution:
    # pull + push span the collective shapes (gather DAG vs combined
    # request/reply); naive/auto fused cells are covered by the staged
    # matrix below and tests/test_plan.py's partitioned matrix
    @pytest.mark.parametrize("name", ["sssp", "wcc", "sv", "chain4"])
    @pytest.mark.parametrize("schedule", ["pull", "push"])
    def test_fused_bitmatches_unfused_both_placements(self, name, schedule):
        g, fields = _setup(name)
        cp = compile_program(alg.ALL[name], g, initial_fields=fields)
        dense, _, counts = cp.run(fields)
        f0 = cp.init_fields(fields)
        for placement, kw in (
            ("replicated", {}), ("partitioned", {"n_shards": 1}),
        ):
            fused = run_bsp(
                cp.prog, g, f0, schedule=schedule, placement=placement, **kw
            )
            unfused = run_bsp(
                cp.prog, g, f0, schedule=schedule, placement=placement,
                fuse=False, **kw
            )
            for f in dense:
                a = np.asarray(dense[f])
                assert np.array_equal(
                    a, np.asarray(fused.fields[f]), equal_nan=True
                ), (name, schedule, placement, f, "fused")
                assert np.array_equal(
                    a, np.asarray(unfused.fields[f]), equal_nan=True
                ), (name, schedule, placement, f, "unfused")
            assert fused.supersteps == counts[FUSED_KEY[schedule]], (
                name, schedule, placement,
            )
            assert unfused.supersteps == counts[UNFUSED_KEY[schedule]], (
                name, schedule, placement,
            )

    @pytest.mark.parametrize("name", ["sssp", "wcc", "sv", "chain4"])
    @pytest.mark.parametrize("schedule", ["naive", "auto"])
    def test_fused_bitmatches_unfused_staged(self, name, schedule):
        g, fields = _setup(name)
        cp = compile_program(alg.ALL[name], g, initial_fields=fields)
        dense, _, counts = cp.run(fields)
        f0 = cp.init_fields(fields)
        fused = run_bsp(cp.prog, g, f0, schedule=schedule)
        unfused = run_bsp(cp.prog, g, f0, schedule=schedule, fuse=False)
        for f in dense:
            a = np.asarray(dense[f])
            assert np.array_equal(
                a, np.asarray(fused.fields[f]), equal_nan=True
            ), (name, schedule, f)
            assert np.array_equal(
                a, np.asarray(unfused.fields[f]), equal_nan=True
            ), (name, schedule, f)
        assert fused.supersteps == counts[FUSED_KEY[schedule]]
        assert unfused.supersteps == counts[UNFUSED_KEY[schedule]]

    def test_sv_saves_at_least_one_superstep_per_iteration(self):
        """The §4.3 acceptance claim, measured: fused S-V execution spends
        ≥ 1 fewer superstep per iteration than fuse=False, matching the
        former STM optimize=True totals."""
        g, _ = _setup("sv")
        cp = compile_program(alg.SV, g)
        f0 = cp.init_fields()
        fused = run_bsp(cp.prog, g, f0)
        unfused = run_bsp(cp.prog, g, f0, fuse=False)
        iters = fused.trips[0]
        assert fused.trips == unfused.trips
        assert unfused.supersteps - fused.supersteps >= iters
        assert fused.supersteps == _former_optimized_count(
            cp.prog, "pull", {0: iters}
        )

    def test_frontier_instrumentation(self):
        """Both executors record the per-iteration fixed-point frontier:
        one series per loop entry, one entry per trip, converging to 0."""
        g, _ = _setup("wcc")
        cp = compile_program(alg.WCC, g)
        f0 = cp.init_fields()
        for placement, kw in (
            ("replicated", {}), ("partitioned", {"n_shards": 1}),
        ):
            res = run_bsp(cp.prog, g, f0, placement=placement, **kw)
            assert len(res.active_sets) == len(res.trips) == 1
            (series,) = res.active_sets
            assert len(series) == res.trips[0]
            assert series[-1] == 0
            assert all(0 <= x <= g.n_vertices for x in series)


def test_request_dedup_report():
    from repro.graph.partition import request_dedup_report

    rep = request_dedup_report([0, 3, 3, 3, 7, 99], 10, bytes_per_value=4)
    assert rep["raw_request_slots"] == 5  # 99 is out of range
    assert rep["deduped_request_slots"] == 3
    assert rep["raw_bytes"] == 5 * 8 and rep["deduped_bytes"] == 3 * 8


SUBPROCESS_TEST = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import algorithms as alg, compile_program
    from repro.graph import generators as G
    from repro.pregel import run_bsp

    # S-V: iteration fusion overlaps the RemoteUpdate's reduce-scatter
    # with the prefetched ReadRound's gather_global in ONE shard_map
    # dispatch; chain4 (random D): duplicate-heavy request sets exercise
    # the deduplicated gather_global bucketing across shards
    for name in ("sv", "chain4"):
        fields = None
        if name == "chain4":
            g = G.erdos_renyi(32, 2.0, directed=False, seed=3)
            rng = np.random.default_rng(3)
            fields = {"D": jnp.asarray(rng.integers(0, 32, 32), jnp.int32)}
        else:
            g = G.erdos_renyi(48, 3.0, directed=False, weighted=True, seed=3)
        cp = compile_program(alg.ALL[name], g, initial_fields=fields)
        dense, _, counts = cp.run(fields)
        f0 = cp.init_fields(fields)
        fused = run_bsp(cp.prog, g, f0, placement="partitioned")
        unfused = run_bsp(cp.prog, g, f0, placement="partitioned",
                          fuse=False)
        for f in dense:
            a = np.asarray(dense[f])
            assert np.array_equal(a, np.asarray(fused.fields[f]),
                                  equal_nan=True), (name, f)
            assert np.array_equal(a, np.asarray(unfused.fields[f]),
                                  equal_nan=True), (name, f)
        assert fused.supersteps == counts["palgol_pull"], name
        assert unfused.supersteps == counts["pull_staged"], name
        print(name, "ok", fused.supersteps, "<", unfused.supersteps)
    print("FUSION_SUBPROCESS_OK")
    """
)


@pytest.mark.subprocess_mesh
def test_fused_partitioned_multidevice():
    """S-V + chain4 fused on the 8-fake-device mesh: bit-identical fields,
    fused (palgol) superstep totals, dedup'd multi-shard gather_global."""
    res = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_TEST],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        timeout=900,
        cwd=str(Path(__file__).resolve().parent.parent),
    )
    assert "FUSION_SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr
