"""End-to-end behaviour tests: the full Palgol → Pregel-on-JAX pipeline."""

import jax
import numpy as np

from repro.core import algorithms as alg
from repro.core import compile_program
from repro.graph import generators as G


def test_end_to_end_sssp_pipeline():
    """Parse → analyze → compile → jit → execute → validate, in one breath."""
    g = G.rmat(8, avg_degree=8, directed=True, weighted=True, seed=0)
    cp = compile_program(alg.SSSP, g)
    out, trips, counts = cp.run()
    D = np.asarray(out["D"])
    # source at 0; reachable set must have finite nonneg distances
    assert D[0] == 0.0
    finite = np.isfinite(D)
    assert finite.sum() >= 1
    assert (D[finite] >= 0).all()
    # the compiled program is a single jittable XLA computation
    lowered = jax.jit(cp.fn).lower(cp.init_fields())
    text = lowered.as_text()
    assert "while" in text  # the fixed-point iteration lowered to lax.while


def test_end_to_end_sv_on_rmat():
    g = G.rmat(8, avg_degree=4, directed=False, seed=1)
    cp = compile_program(alg.SV, g)
    out, trips, counts = cp.run()
    D = np.asarray(out["D"])
    # component representative is a fixed point of D (forest collapsed)
    assert np.array_equal(D[D], D)
    # superstep economy (the paper's headline Table-5 result, structurally)
    assert counts["palgol_push"] < counts["naive"]


def test_whole_program_is_one_xla_module():
    """Sequences + iterations fuse into one compiled module (state merging
    taken to its logical conclusion on a shared-address-space machine)."""
    g = G.erdos_renyi(64, 4.0, seed=2)
    cp = compile_program(alg.WCC, g)
    compiled = jax.jit(cp.fn).lower(cp.init_fields()).compile()
    assert compiled.cost_analysis() is not None
