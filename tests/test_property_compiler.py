"""Property-based compiler fuzzing: random Palgol programs, random graphs —
the dense compiled executor must agree with the per-vertex interpreter, and
the three superstep accountings must be consistently ordered.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ast, compile_program, interpret
from repro.core.logic import pull_rounds, push_rounds
from repro.graph import generators as G


# --- random program generator (a bounded but expressive family) -----------

FIELDS = ["A", "B", "C"]
INT_FIELDS = ["P", "Q"]  # vertex-id-valued (usable as chain links)


@st.composite
def vertex_expr(draw, depth=0):
    """Int/float-valued expression in vertex context."""
    choices = ["const", "field", "id"]
    if depth < 2:
        choices += ["binop", "chain", "reduce", "cond"]
    kind = draw(st.sampled_from(choices))
    if kind == "const":
        return ast.Const(draw(st.integers(-3, 3)))
    if kind == "id":
        return ast.FieldAccess("Id", ast.Var("v"))
    if kind == "field":
        return ast.FieldAccess(draw(st.sampled_from(FIELDS)), ast.Var("v"))
    if kind == "chain":
        f = draw(st.sampled_from(INT_FIELDS))
        g = draw(st.sampled_from(FIELDS + INT_FIELDS))
        # G[F[v]] — a depth-2 chain access
        return ast.FieldAccess(g, ast.FieldAccess(f, ast.Var("v")))
    if kind == "binop":
        op = draw(st.sampled_from(["+", "-", "*"]))
        return ast.BinOp(
            op, draw(vertex_expr(depth + 1)), draw(vertex_expr(depth + 1))
        )
    if kind == "cond":
        return ast.Cond(
            ast.BinOp(
                "<", draw(vertex_expr(depth + 1)), draw(vertex_expr(depth + 1))
            ),
            draw(vertex_expr(depth + 1)),
            draw(vertex_expr(depth + 1)),
        )
    # reduce: a neighborhood comprehension
    func = draw(st.sampled_from(["sum", "minimum", "maximum", "count"]))
    body = (
        ast.Const(1)
        if func == "count"
        else ast.FieldAccess(
            draw(st.sampled_from(FIELDS)), ast.EdgeProp("e", "id")
        )
    )
    return ast.Reduce(
        func, body, "e", ast.EdgeList("nbr", ast.Var("v")), ()
    )


@st.composite
def step(draw):
    stmts = []
    n_stmts = draw(st.integers(1, 3))
    # one combiner per field per step (mixed combiners are rejected by the
    # compiler as order-dependent — see analysis.py)
    remote_op = {
        f: draw(st.sampled_from(["+=", "<?=", ">?="])) for f in FIELDS
    }
    for _ in range(n_stmts):
        kind = draw(st.sampled_from(["local", "local", "remote", "if"]))
        field = draw(st.sampled_from(FIELDS))
        if kind == "local":
            op = draw(st.sampled_from([":=", "+=", "<?=", ">?="]))
            stmts.append(ast.LocalWrite(field, op, draw(vertex_expr()), "v"))
        elif kind == "remote":
            op = remote_op[field]
            target = ast.FieldAccess(
                draw(st.sampled_from(INT_FIELDS)), ast.Var("v")
            )
            stmts.append(ast.RemoteWrite(field, target, op, draw(vertex_expr())))
        else:
            stmts.append(
                ast.If(
                    ast.BinOp("<", draw(vertex_expr()), draw(vertex_expr())),
                    (ast.LocalWrite(field, ":=", draw(vertex_expr()), "v"),),
                    (),
                )
            )
    return ast.Step("v", tuple(stmts))


@st.composite
def program(draw):
    items = [draw(step()) for _ in range(draw(st.integers(1, 2)))]
    if draw(st.booleans()):
        items.append(ast.Iter(draw(step()), ("A",)))
    return ast.Seq(tuple(items)) if len(items) > 1 else items[0]


@settings(max_examples=25, deadline=None)
@given(program(), st.integers(0, 10**6))
def test_compiled_matches_interpreter_on_random_programs(prog, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 24))
    g = G.erdos_renyi(n, 3.0, directed=False, seed=seed % 100)
    fields = {
        "A": jnp.asarray(rng.integers(-4, 4, n).astype(np.int32)),
        "B": jnp.asarray(rng.integers(-4, 4, n).astype(np.int32)),
        "C": jnp.asarray(rng.integers(-4, 4, n).astype(np.int32)),
        "P": jnp.asarray(rng.integers(0, n, n).astype(np.int32)),
        "Q": jnp.asarray(rng.integers(0, n, n).astype(np.int32)),
    }
    cp = compile_program(prog, g, initial_fields=fields, max_iters=12)
    out, trips, counts = cp.run(fields)
    ref, rtrips = interpret(prog, g, fields, max_iters=12)
    # iteration counts may differ only if max_iters was hit
    if trips[: len(rtrips)] == rtrips:
        for f in sorted(out):
            if f.startswith("_"):
                continue
            a, b = np.asarray(out[f]), np.asarray(ref[f])
            assert np.array_equal(a, b), (f, a, b)
    # the accounting orderings always hold
    assert counts["palgol_pull"] <= counts["palgol_push"] <= counts["naive"]


@settings(max_examples=50, deadline=None)
@given(st.lists(st.sampled_from(["D", "E"]), min_size=1, max_size=8))
def test_round_count_orderings(chain):
    """pull ≤ push ≤ naive(2·(k−1)) for every chain pattern."""
    pat = tuple(chain)
    k = len(pat)
    assert pull_rounds(pat) <= push_rounds(pat)
    if k > 1:
        assert push_rounds(pat) <= 2 * (k - 1)
        assert pull_rounds(pat) >= 1


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 64))
def test_pull_rounds_log2(k):
    import math

    assert pull_rounds(("D",) * k) == max(0, math.ceil(math.log2(k)))
