"""Suite-wide config: CPU pinning, deterministic seeds, dep fallbacks.

Loaded before any test module imports, so environment pins land before
jax initializes a backend and the hypothesis fallback is in place before
``from hypothesis import given`` runs.
"""

import os
import sys
from pathlib import Path

# -- CPU-only determinism ---------------------------------------------------
# Pin the platform before jax picks a backend: the suite's oracles are all
# CPU references, and CI machines must not accidentally grab a GPU/TPU.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

SRC = Path(__file__).resolve().parent.parent / "src"
if str(SRC) not in sys.path:  # belt-and-braces next to pyproject pythonpath
    sys.path.insert(0, str(SRC))

# -- hypothesis fallback ----------------------------------------------------
# Hermetic images may lack hypothesis; substitute the deterministic stub so
# the property tests still run as seeded random testing (same test code).
try:
    import hypothesis  # noqa: F401
except ImportError:
    from repro.testing import hypothesis_stub

    sys.modules["hypothesis"] = hypothesis_stub
    sys.modules["hypothesis.strategies"] = hypothesis_stub.strategies

import numpy as np
import pytest

# Mesh-API shims (jax.sharding.AxisType / make_mesh(axis_types=...)) for
# jaxlib < 0.4.38 — tests build meshes directly, so install suite-wide.
from repro.dist import compat  # noqa: E402, F401

#: the one seed every fixture derives from — change here, change everywhere
SUITE_SEED = 170309542  # arXiv 1703.09542, digits only


@pytest.fixture
def rng():
    """Fresh, fixed-seed numpy Generator (per-test, order-independent)."""
    return np.random.default_rng(SUITE_SEED)


@pytest.fixture
def prng_key():
    """Fixed jax PRNG key (imported lazily so collection never inits jax)."""
    import jax

    return jax.random.PRNGKey(SUITE_SEED)
