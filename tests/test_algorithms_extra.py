"""Extra stdlib algorithms (BFS, k-core, label propagation) vs ground truth."""

import collections

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import compile_program, interpret
from repro.core.analysis import CompileError
from repro.graph import generators as G


def _adj(g, directed=False):
    src, dst, m = map(np.asarray, (g.src, g.dst, g.edge_mask))
    out = collections.defaultdict(set)
    for s, d, mm in zip(src, dst, m):
        if mm:
            out[int(d)].add(int(s))  # in-neighbors of d
    return out


@pytest.mark.parametrize("seed", [0, 1])
def test_bfs_levels(seed):
    g = G.erdos_renyi(60, 4.0, directed=True, seed=seed)
    cp = compile_program(alg.BFS, g)
    out, trips, counts = cp.run()
    L = np.asarray(out["L"])
    # reference BFS over in-edge transpose (v pulls from In ⇒ edge u→v)
    src, dst, m = map(np.asarray, (g.src, g.dst, g.edge_mask))
    import math

    ref = np.full(g.n_vertices, math.inf)
    ref[0] = 0
    frontier = [0]
    lvl = 0
    while frontier:
        nxt = []
        for u in frontier:
            for s, d, mm in zip(src, dst, m):
                if mm and s == u and ref[d] == math.inf:
                    ref[d] = lvl + 1
                    nxt.append(int(d))
        frontier = nxt
        lvl += 1
    assert np.allclose(L, ref, equal_nan=True)
    ref_i, _ = interpret(alg.BFS, g)
    assert np.allclose(L, ref_i["L"], equal_nan=True)


@pytest.mark.parametrize("k", [2, 3])
def test_kcore(k):
    g = G.erdos_renyi(50, 5.0, directed=False, seed=3)
    K = jnp.full((g.n_vertices,), k, jnp.int32)
    cp = compile_program(alg.KCORE, g, initial_fields={"K": K})
    out, _, _ = cp.run({"K": K})
    alive = np.asarray(out["Alive"])
    # ground truth: iterative peeling
    src, dst, m = map(np.asarray, (g.src, g.dst, g.edge_mask))
    ref = np.ones(g.n_vertices, bool)
    changed = True
    while changed:
        changed = False
        deg = np.zeros(g.n_vertices, int)
        for s, d, mm in zip(src, dst, m):
            if mm and ref[s] and ref[d]:
                deg[d] += 1
        for v in range(g.n_vertices):
            if ref[v] and deg[v] < k:
                ref[v] = False
                changed = True
    assert np.array_equal(alive, ref)
    # every survivor has ≥ k alive neighbors (the k-core invariant)
    deg = np.zeros(g.n_vertices, int)
    for s, d, mm in zip(src, dst, m):
        if mm and alive[s] and alive[d]:
            deg[d] += 1
    assert all(deg[v] >= k for v in range(g.n_vertices) if alive[v])


def test_label_prop_matches_wcc_on_undirected():
    # min-label propagation on an undirected graph converges to the
    # component minimum — same partition as WCC
    g = G.erdos_renyi(80, 3.0, directed=False, seed=4)
    lp, _, _ = compile_program(alg.LABEL_PROP, g).run()
    wcc, _, _ = compile_program(alg.WCC, g).run()
    assert np.array_equal(np.asarray(lp["C"]), np.asarray(wcc["C"]))


def test_mixed_remote_combiners_rejected():
    src = """
for v in V
    remote A[Id[v]] += 1
    remote A[Id[v]] <?= 0
end
"""
    g = G.cycle(8)
    with pytest.raises(CompileError, match="mixed combiners"):
        compile_program(src, g, initial_fields={
            "A": jnp.zeros((8,), jnp.int32)
        })
