"""Property tests for the graph substrate (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.graph import generators as G
from repro.graph import ops as gops
from repro.graph.sampler import CSR, sample_khop
from repro.graph.structure import from_edge_list


@st.composite
def small_graph(draw):
    n = draw(st.integers(2, 24))
    m = draw(st.integers(0, 60))
    src = draw(
        st.lists(st.integers(0, n - 1), min_size=m, max_size=m)
    )
    dst = draw(
        st.lists(st.integers(0, n - 1), min_size=m, max_size=m)
    )
    pad = draw(st.integers(0, 8))
    return from_edge_list(
        np.array(src, np.int32),
        np.array(dst, np.int32),
        n,
        pad_to=m + pad,
    )


@settings(max_examples=40, deadline=None)
@given(small_graph(), st.integers(0, 2**31 - 1))
def test_segment_sum_matches_numpy(g, seed):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=g.n_edges).astype(np.float32)
    out = gops.segment_reduce(
        jnp.asarray(vals), g.dst, g.n_vertices, "sum",
        indices_are_sorted=True, mask=g.edge_mask,
    )
    expect = np.zeros(g.n_vertices, np.float32)
    dst, m = np.asarray(g.dst), np.asarray(g.edge_mask)
    for i in range(g.n_edges):
        if m[i]:
            expect[dst[i]] += vals[i]
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(small_graph(), st.sampled_from(["min", "max", "or", "and"]))
def test_segment_reduce_identities_on_empty(g, op):
    """Empty segments must yield the combiner identity."""
    if op in ("or", "and"):
        vals = jnp.ones((g.n_edges,), jnp.bool_)
    else:
        vals = jnp.ones((g.n_edges,), jnp.float32)
    out = gops.segment_reduce(
        vals, g.dst, g.n_vertices, op, indices_are_sorted=True, mask=g.edge_mask
    )
    deg = np.asarray(gops.in_degrees(g))
    o = np.asarray(out)
    for v in range(g.n_vertices):
        if deg[v] == 0:
            if op == "min":
                assert o[v] == np.inf
            elif op == "max":
                assert o[v] == -np.inf
            elif op == "or":
                assert not o[v]
            else:
                assert o[v]


@settings(max_examples=40, deadline=None)
@given(small_graph(), st.integers(0, 2**31 - 1), st.sampled_from(["sum", "min", "max"]))
def test_scatter_combine_matches_loop(g, seed, op):
    rng = np.random.default_rng(seed)
    vals = rng.normal(size=g.n_edges).astype(np.float32)
    buf0 = rng.normal(size=g.n_vertices).astype(np.float32)
    out = gops.scatter_combine(
        jnp.asarray(buf0), g.dst, jnp.asarray(vals), op, mask=g.edge_mask
    )
    expect = buf0.copy()
    dst, m = np.asarray(g.dst), np.asarray(g.edge_mask)
    f = {"sum": lambda a, b: a + b, "min": min, "max": max}[op]
    for i in range(g.n_edges):
        if m[i]:
            expect[dst[i]] = f(expect[dst[i]], vals[i])
    np.testing.assert_allclose(np.asarray(out), expect, rtol=1e-5, atol=1e-5)


def test_edge_softmax_normalizes():
    g = G.erdos_renyi(50, 5.0, seed=1)
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.normal(size=g.n_edges).astype(np.float32))
    sm = gops.edge_softmax(
        scores, g.dst, g.n_vertices, mask=g.edge_mask, indices_are_sorted=True
    )
    sums = gops.segment_reduce(
        sm, g.dst, g.n_vertices, "sum", indices_are_sorted=True, mask=g.edge_mask
    )
    deg = np.asarray(gops.in_degrees(g))
    s = np.asarray(sums)
    assert np.all((np.abs(s - 1) < 1e-5) | (deg == 0))


def test_symmetrize_produces_symmetric_graph():
    g = G.erdos_renyi(40, 4.0, directed=False, seed=2)
    src, dst, m = map(np.asarray, (g.src, g.dst, g.edge_mask))
    edges = set(zip(src[m].tolist(), dst[m].tolist()))
    assert all((d, s) in edges for s, d in edges)


class TestSampler:
    def test_khop_shapes_static(self):
        g = G.erdos_renyi(100, 6.0, seed=3)
        csr = CSR.from_graph(g)
        seeds = jnp.arange(8)
        blocks = sample_khop(csr, seeds, [5, 3], jax.random.PRNGKey(0))
        assert blocks[0].neighbors.shape == (8, 5)
        assert blocks[1].neighbors.shape == (40, 3)

    def test_sampled_neighbors_are_real_neighbors(self):
        g = G.erdos_renyi(60, 5.0, seed=4)
        csr = CSR.from_graph(g)
        seeds = jnp.arange(10)
        (blk,) = sample_khop(csr, seeds, [7], jax.random.PRNGKey(1))
        indptr = np.asarray(csr.indptr)
        indices = np.asarray(csr.indices)
        nbrs = np.asarray(blk.neighbors)
        mask = np.asarray(blk.mask)
        for i, v in enumerate(range(10)):
            true_nbrs = set(indices[indptr[v]:indptr[v + 1]].tolist())
            for j in range(7):
                if mask[i, j]:
                    assert nbrs[i, j] in true_nbrs
                else:
                    assert nbrs[i, j] == g.n_vertices

    def test_zero_degree_masked(self):
        g = from_edge_list(np.array([0], np.int32), np.array([1], np.int32), 4)
        csr = CSR.from_graph(g)
        (blk,) = sample_khop(csr, jnp.arange(4), [3], jax.random.PRNGKey(2))
        mask = np.asarray(blk.mask)
        assert mask[1].all()  # vertex 1 has in-neighbor 0
        assert not mask[0].any() and not mask[2].any() and not mask[3].any()
