"""Staged BSP executor: result equality + superstep accounting validation."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import compile_program
from repro.graph import generators as G
from repro.pregel import run_bsp


def _setup(name, seed):
    fields = None
    if name in ("sssp", "pagerank", "scc"):
        g = G.erdos_renyi(40, 4.0, directed=True, weighted=True, seed=seed)
    elif name == "bipartite_matching":
        g, side = G.random_bipartite(15, 15, 3.0, seed=seed)
        fields = {"Side": jnp.asarray(side)}
    elif name == "mis":
        g = G.erdos_renyi(40, 3.0, directed=False, seed=seed)
        rng = np.random.default_rng(seed)
        fields = {"P": jnp.asarray(rng.random(g.n_vertices), jnp.float32)}
    elif name == "chain4":
        g = G.erdos_renyi(30, 2.0, directed=False, seed=seed)
        rng = np.random.default_rng(seed)
        fields = {"D": jnp.asarray(rng.integers(0, 30, 30), jnp.int32)}
    else:
        g = G.erdos_renyi(40, 3.0, directed=False, weighted=True, seed=seed)
    return g, fields


ALGS = ["sssp", "sv", "wcc", "mis", "bipartite_matching", "mwm", "chain4"]


@pytest.mark.parametrize("name", ALGS)
def test_bsp_matches_dense(name):
    g, fields = _setup(name, seed=3)
    cp = compile_program(alg.ALL[name], g, initial_fields=fields)
    dense, trips, counts = cp.run(fields)
    f0 = cp.init_fields(fields)
    for schedule in ("pull", "naive"):
        res = run_bsp(cp.prog, g, f0, schedule=schedule)
        for f in dense:
            a, b = np.asarray(dense[f]), np.asarray(res.fields[f])
            if a.dtype == np.float32:
                assert np.allclose(a, b, rtol=1e-5, equal_nan=True), (name, f)
            else:
                assert np.array_equal(a, b), (name, schedule, f)


@pytest.mark.parametrize("name", ALGS)
def test_superstep_accounting_matches_execution(name):
    """The STM cost models must predict the staged executor's actual count
    — fused (the default, ``palgol_*``/``fused_*`` models) and unfused
    (``fuse=False``, the historical per-op expansion) alike."""
    g, fields = _setup(name, seed=4)
    cp = compile_program(alg.ALL[name], g, initial_fields=fields)
    _, trips, counts = cp.run(fields)
    f0 = cp.init_fields(fields)
    exec_pull = run_bsp(cp.prog, g, f0, schedule="pull")
    assert exec_pull.supersteps == counts["palgol_pull"], name
    exec_naive = run_bsp(cp.prog, g, f0, schedule="naive")
    assert exec_naive.supersteps == counts["fused_naive"], name
    exec_pull_unfused = run_bsp(cp.prog, g, f0, schedule="pull", fuse=False)
    assert exec_pull_unfused.supersteps == counts["pull_staged"], name
    exec_naive_unfused = run_bsp(cp.prog, g, f0, schedule="naive", fuse=False)
    assert exec_naive_unfused.supersteps == counts["naive"], name


def test_sv_superstep_reduction_structure():
    """Paper Table 5: S-V compiled by Palgol takes ~half the supersteps of
    the manual (request/reply, unfused) implementation."""
    g = G.erdos_renyi(200, 4.0, directed=False, seed=9)
    cp = compile_program(alg.SV, g)
    _, trips, counts = cp.run()
    reduction = 1 - counts["palgol_push"] / counts["naive"]
    assert reduction >= 0.35  # paper reports 46–52%
    # beyond-paper pull schedule is at least as good
    assert counts["palgol_pull"] <= counts["palgol_push"]


def test_pagerank_superstep_parity():
    """Paper Table 5: PR Palgol == manual superstep count (fusion makes the
    nbr-send free; manual message-driven PR is 1/iteration too)."""
    g = G.erdos_renyi(100, 4.0, directed=True, seed=10)
    cp = compile_program(alg.PAGERANK, g)
    _, trips, counts = cp.run()
    iters = trips[0]
    # fused: init-step + iter-init merged + 1/iter
    assert counts["palgol_push"] == iters + 1
    assert counts["palgol_pull"] == iters + 1
