"""`repro.graph.partition` tests: invariants, halo closure, equivalence.

Three layers:

* host-side partitioner invariants (+ hypothesis/stub property tests):
  every edge assigned exactly once, greedy balance bound, halo closure,
  partition→unpartition identity;
* single-device (S=1) partitioned execution — the full shard_map/collective
  machinery on a 1-shard mesh, runnable in-process;
* 8-fake-device subprocess: SSSP and connected components bit-match the
  dense single-device executor with identical superstep counts, pointer
  doubling (S-V, chain4) included — the ISSUE-2 acceptance gate.
"""

import subprocess
import sys
import textwrap
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import algorithms as alg
from repro.core import compile_program
from repro.graph import generators as G
from repro.graph.partition import (
    comm_bytes_report,
    edge_balanced_ranges,
    partition_field,
    partition_graph,
    partition_stats,
    unpartition_field,
)
from repro.pregel import run_bsp
from repro.pregel.runtime import _StagedStep, read_superstep_count
from repro.core.analysis import iter_steps
from repro.core import ast as past


# bool ||= / &&= remote writes at computed and edge targets: exercises the
# or/and branch of the cross-shard scatter_reduce (int min/max transport +
# re-threshold), which no library algorithm reaches
BOOL_COMBINER_PROG = """
for v in V
    local Flag[v] := (Id[v] % 7 == 0)
    local Tgt[v] := (Id[v] * 13) % numV
    local All[v] := true
end
for v in V
    if (Flag[v])
        remote Flag[Tgt[v]] ||= true
        for (e <- Nbr[v])
            remote Flag[e.id] ||= true
    for (e <- Nbr[v])
        remote All[e.id] &&= (Id[v] % 2 == 0)
end
"""


def _real_edges(g):
    m = np.asarray(g.edge_mask)
    return list(
        zip(
            np.asarray(g.src)[m].tolist(),
            np.asarray(g.dst)[m].tolist(),
        )
    )


class TestPartitioner:
    def test_every_edge_assigned_exactly_once(self):
        g = G.erdos_renyi(60, 5.0, directed=True, weighted=True, seed=2)
        pg = partition_graph(g, 4)
        starts = np.asarray(pg.starts)
        got = []
        for s in range(pg.n_shards):
            m = np.asarray(pg.emask[s])
            src = np.asarray(pg.src_g[s])[m]
            dst = np.asarray(pg.dst_l[s])[m] + starts[s]
            # ownership: every assigned edge's dst is owned by shard s
            assert np.all((dst >= starts[s]) & (dst < starts[s + 1]))
            got += list(zip(src.tolist(), dst.tolist()))
        assert sorted(got) == sorted(_real_edges(g))
        # push ordering too
        got_t = []
        for s in range(pg.n_shards):
            m = np.asarray(pg.t_emask[s])
            src = np.asarray(pg.t_src_l[s])[m] + starts[s]
            dst = np.asarray(pg.t_dst_g[s])[m]
            assert np.all((src >= starts[s]) & (src < starts[s + 1]))
            got_t += list(zip(src.tolist(), dst.tolist()))
        assert sorted(got_t) == sorted(_real_edges(g))

    def test_edge_balance_bound(self):
        g = G.rmat(10, avg_degree=8.0, directed=True, seed=7)
        n_shards = 8
        bounds = edge_balanced_ranges(g, n_shards)
        pg = partition_graph(g, n_shards, bounds=bounds)
        stats = partition_stats(pg)
        # greedy prefix bound: shard weight ≤ total/S + max vertex weight
        dst = np.asarray(g.dst)[np.asarray(g.edge_mask)]
        t_src = np.asarray(g.t_src)[np.asarray(g.t_mask)]
        w = np.ones(g.n_vertices, np.int64)
        np.add.at(w, dst, 1)
        np.add.at(w, t_src, 1)
        per_shard = [
            int(w[bounds[s]: bounds[s + 1]].sum()) for s in range(n_shards)
        ]
        bound = w.sum() / n_shards + w.max()
        assert max(per_shard) <= bound + 1e-9
        # and the per-shard assigned-edge counts inherit the balance
        assert max(stats["pull_edges_per_shard"]) <= bound

    def test_halo_closed_under_edge_patterns(self):
        """Every neighbor id a program's edge traversals read is owned or
        in the static ghost list (halo closure for ``F[e.id]`` patterns)."""
        g = G.erdos_renyi(80, 4.0, directed=False, weighted=True, seed=3)
        pg = partition_graph(g, 5)
        starts = np.asarray(pg.starts)
        n = g.n_vertices
        for nbr, emask, halo in (
            (pg.src_g, pg.emask, pg.halo_in),
            (pg.t_dst_g, pg.t_emask, pg.halo_out),
        ):
            for s in range(pg.n_shards):
                ids = np.asarray(nbr[s])[np.asarray(emask[s])]
                own = (ids >= starts[s]) & (ids < starts[s + 1])
                ghost = np.asarray(halo.ghost_ids[s])
                ghost = ghost[ghost < n]
                assert np.all(np.isin(ids[~own], ghost)), s
                # ghosts are never owned and are sorted unique
                assert not np.any((ghost >= starts[s]) & (ghost < starts[s + 1]))
                assert np.all(np.diff(ghost) > 0)

    def test_partition_unpartition_roundtrip(self):
        g = G.erdos_renyi(57, 3.0, directed=True, seed=4)
        pg = partition_graph(g, 7)
        rng = np.random.default_rng(0)
        for arr in (
            rng.normal(size=57).astype(np.float32),
            rng.integers(0, 100, 57).astype(np.int32),
            rng.random(57) < 0.5,
        ):
            x = jnp.asarray(arr)
            assert np.array_equal(
                np.asarray(unpartition_field(pg, partition_field(pg, x))),
                arr,
            )

    def test_rejects_more_shards_than_vertices(self):
        g = G.cycle(4)
        with pytest.raises(ValueError):
            edge_balanced_ranges(g, 5)


class TestPartitionProperties:
    """Property tests (hypothesis, or the deterministic stub in hermetic
    images): invariants hold across random graph shapes and shard counts."""

    @given(
        n=st.integers(min_value=8, max_value=96),
        deg=st.integers(min_value=1, max_value=6),
        n_shards=st.integers(min_value=1, max_value=8),
        seed=st.integers(min_value=0, max_value=10_000),
    )
    @settings(max_examples=20, deadline=None)
    def test_partition_invariants(self, n, deg, n_shards, seed):
        n_shards = min(n_shards, n)
        g = G.erdos_renyi(n, float(deg), directed=True, seed=seed)
        pg = partition_graph(g, n_shards)
        starts = np.asarray(pg.starts)
        assert starts[0] == 0 and starts[-1] == n
        assert np.all(np.diff(starts) >= 1)
        # edge conservation
        total = sum(int(np.asarray(pg.emask[s]).sum()) for s in range(n_shards))
        assert total == pg.n_edges
        # round trip
        x = jnp.arange(n, dtype=jnp.int32)
        assert np.array_equal(
            np.asarray(unpartition_field(pg, partition_field(pg, x))),
            np.arange(n, dtype=np.int32),
        )


class TestSuperstepAccounting:
    """read_superstep_count must mirror the staged executor exactly — the
    partitioned path charges its supersteps through it."""

    @pytest.mark.parametrize(
        "name", ["sssp", "sv", "wcc", "mis", "mwm", "chain4", "pagerank"]
    )
    @pytest.mark.parametrize("schedule", ["pull", "naive"])
    def test_matches_staged_stage_count(self, name, schedule):
        g = G.erdos_renyi(30, 3.0, directed=False, weighted=True, seed=1)
        fields = None
        if name == "chain4":
            fields = {"D": jnp.zeros((30,), jnp.int32)}
        elif name == "mis":
            rng = np.random.default_rng(1)
            fields = {"P": jnp.asarray(rng.random(30), jnp.float32)}
        cp = compile_program(alg.ALL[name], g, initial_fields=fields)
        for step in iter_steps(cp.prog):
            if not isinstance(step, past.Step):
                continue
            staged = _StagedStep(step, g, schedule)
            assert read_superstep_count(step, schedule) == len(
                staged.read_stage_fns()
            ), (name, schedule)


class TestPartitionedExecutionSingleShard:
    """S=1 exercises the whole partitioned machinery in-process."""

    @pytest.mark.parametrize(
        "name",
        ["sssp", "wcc", "sv", "mwm", "chain4", "mis", "bipartite_matching"],
    )
    def test_matches_dense(self, name):
        fields = None
        if name == "sssp":
            g = G.erdos_renyi(40, 4.0, directed=True, weighted=True, seed=3)
        elif name == "chain4":
            g = G.erdos_renyi(30, 2.0, directed=False, seed=3)
            rng = np.random.default_rng(3)
            fields = {"D": jnp.asarray(rng.integers(0, 30, 30), jnp.int32)}
        elif name == "mis":
            g = G.erdos_renyi(40, 3.0, directed=False, seed=3)
            rng = np.random.default_rng(3)
            fields = {"P": jnp.asarray(rng.random(40), jnp.float32)}
        elif name == "bipartite_matching":
            g, side = G.random_bipartite(15, 15, 3.0, seed=3)
            fields = {"Side": jnp.asarray(side)}
        else:
            g = G.erdos_renyi(40, 3.0, directed=False, weighted=True, seed=3)
        cp = compile_program(alg.ALL[name], g, initial_fields=fields)
        dense, _, counts = cp.run(fields)
        f0 = cp.init_fields(fields)
        res = run_bsp(
            cp.prog, g, f0, schedule="pull",
            placement="partitioned", n_shards=1,
        )
        for f in dense:
            assert np.array_equal(
                np.asarray(dense[f]), np.asarray(res.fields[f]),
                equal_nan=True,
            ), (name, f)
        # default execution is the §4.3-fused plan — palgol_pull totals
        assert res.supersteps == counts["palgol_pull"], name
        unfused = run_bsp(
            cp.prog, g, f0, schedule="pull",
            placement="partitioned", n_shards=1, fuse=False,
        )
        assert unfused.supersteps == counts["pull_staged"], name

    def test_bool_combiner_remote_writes(self):
        g = G.erdos_renyi(40, 3.0, directed=False, seed=5)
        cp = compile_program(BOOL_COMBINER_PROG, g)
        dense, _, counts = cp.run()
        res = run_bsp(
            cp.prog, g, cp.init_fields(),
            placement="partitioned", n_shards=1,
        )
        for f in dense:
            assert np.array_equal(
                np.asarray(dense[f]), np.asarray(res.fields[f])
            ), f
        assert res.supersteps == counts["palgol_pull"]

    def test_rejects_unknown_schedule(self):
        g = G.cycle(8)
        cp = compile_program(alg.WCC, g)
        with pytest.raises(ValueError):
            run_bsp(
                cp.prog, g, cp.init_fields(), schedule="bogus",
                placement="partitioned", n_shards=1,
            )


class TestCommBytes:
    def test_partitioned_below_replicated_on_local_graph(self):
        """ISSUE-2 acceptance: on a graph with ≥ 8× more vertices than halo
        entries, the partitioned path's per-superstep bytes (padded — what
        the static-shape all_to_all actually moves) are below replicated."""
        g = G.grid2d(512, 8)
        rep = comm_bytes_report(g, 8)
        assert rep["vertices_per_halo_entry"] >= 8.0
        assert (
            rep["partitioned_padded_bytes_per_superstep"]
            < rep["replicated_bytes_per_superstep"]
        )
        assert (
            rep["partitioned_payload_bytes_per_superstep"]
            <= rep["partitioned_padded_bytes_per_superstep"]
        )

    def test_benchmark_report_shape(self):
        """The benchmark's comm_comparison (what writes
        BENCH_palgol_mesh.json) carries both layouts for every graph."""
        root = str(Path(__file__).resolve().parent.parent)
        sys.path.insert(0, root)
        try:
            from benchmarks.palgol_mesh import comm_comparison
        finally:
            sys.path.remove(root)
        bench = comm_comparison(4)
        assert bench["n_shards"] == 4
        for rec in bench["per_graph"].values():
            assert rec["replicated_bytes_per_superstep"] > 0
            assert rec["partitioned_padded_bytes_per_superstep"] > 0


SUBPROCESS_TEST = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax.numpy as jnp
    from repro.core import algorithms as alg, compile_program
    from repro.graph import generators as G
    from repro.pregel import run_bsp

    # bool ||= / &&= remote writes: the or/and scatter_reduce branch only
    # engages its collective transport with more than one shard
    BOOL_PROG = '''
    for v in V
        local Flag[v] := (Id[v] % 7 == 0)
        local Tgt[v] := (Id[v] * 13) % numV
        local All[v] := true
    end
    for v in V
        if (Flag[v])
            remote Flag[Tgt[v]] ||= true
            for (e <- Nbr[v])
                remote Flag[e.id] ||= true
        for (e <- Nbr[v])
            remote All[e.id] &&= (Id[v] % 2 == 0)
    end
    '''
    import textwrap
    progs = dict(alg.ALL)
    progs["bool_comb"] = textwrap.dedent(BOOL_PROG)

    # sssp / wcc: the acceptance pair; sv + chain4: remote writes and
    # pull-mode pointer doubling across shards; mwm: argmax + stop/halted;
    # bool_comb: or/and combiners
    for name in ("sssp", "wcc", "sv", "chain4", "mwm", "bool_comb"):
        fields = None
        if name == "sssp":
            g = G.erdos_renyi(48, 4.0, directed=True, weighted=True, seed=3)
        elif name == "chain4":
            g = G.erdos_renyi(32, 2.0, directed=False, seed=3)
            rng = np.random.default_rng(3)
            fields = {"D": jnp.asarray(rng.integers(0, 32, 32), jnp.int32)}
        else:
            g = G.erdos_renyi(48, 3.0, directed=False, weighted=True, seed=3)
        cp = compile_program(progs[name], g, initial_fields=fields)
        dense, _, counts = cp.run(fields)
        f0 = cp.init_fields(fields)
        res = run_bsp(cp.prog, g, f0, schedule="pull",
                      placement="partitioned")
        for f in dense:
            a, b = np.asarray(dense[f]), np.asarray(res.fields[f])
            assert np.array_equal(a, b, equal_nan=True), (name, f)
        assert res.supersteps == counts["palgol_pull"], (
            name, res.supersteps, counts["palgol_pull"])
        print(name, "ok", res.supersteps)
    print("PARTITION_SUBPROCESS_OK")
    """
)


@pytest.mark.subprocess_mesh
def test_partitioned_multidevice_equivalence():
    """SSSP + CC (+ SV, chain4) on the 8-fake-device mesh: bit-identical
    fields and identical STM superstep counts vs the dense path."""
    res = subprocess.run(
        [sys.executable, "-c", SUBPROCESS_TEST],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        timeout=560,
        cwd=str(Path(__file__).resolve().parent.parent),
    )
    assert "PARTITION_SUBPROCESS_OK" in res.stdout, res.stdout + res.stderr
