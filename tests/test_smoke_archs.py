"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs. Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation) — see launch/dryrun.py."""


import jax
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import gnn_full_batch, recsys_batches
from repro.models.gnn import models as gm
from repro.models.recsys import autoint
from repro.models.transformer import model as tm
from repro.optim import AdamWConfig, adamw_init, adamw_update

LM_ARCHS = [
    "h2o-danube-1.8b",
    "qwen3-32b",
    "qwen2.5-32b",
    "qwen3-moe-235b-a22b",
    "deepseek-moe-16b",
]
GNN_ARCHS = ["pna", "graphsage-reddit", "graphcast", "gat-cora"]


def _no_nans(tree):
    for leaf in jax.tree_util.tree_leaves(tree):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float64))), "NaN/Inf"


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    spec = configs.get_spec(arch)
    cfg = spec.reduced
    params = tm.init(jax.random.PRNGKey(0), cfg)
    b, s = 2, 32
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                     cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                     cfg.vocab_size),
    }
    # forward shapes
    hidden, _ = tm.forward(params, batch["tokens"], cfg)
    assert hidden.shape == (b, s, cfg.d_model)
    logits = tm.logits_from_hidden(params, hidden, cfg)
    assert logits.shape == (b, s, cfg.vocab_size)
    _no_nans(logits)
    # one full train step (grad + AdamW)
    oc = AdamWConfig(lr=1e-3)
    st = adamw_init(params, oc)
    loss, g = jax.value_and_grad(lambda p: tm.loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    new_params, _ = adamw_update(g, st, params, oc)
    _no_nans(new_params)
    # decode step shape
    logits_pre, cache = tm.prefill(params, batch["tokens"], cfg, capacity=64)
    dl, cache2 = tm.decode_step(
        params, cache, batch["tokens"][:, :1], cfg
    )
    assert dl.shape == (b, cfg.vocab_size)
    assert int(cache2["length"][0]) == s + 1
    _no_nans(dl)


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_smoke(arch):
    spec = configs.get_spec(arch)
    cfg = spec.reduced
    batch = gnn_full_batch(
        64, 4.0, cfg.d_in, cfg.n_out, seed=3, task=cfg.task, n_out=cfg.n_out
    )
    params = gm.init(jax.random.PRNGKey(0), cfg)
    out = gm.forward(params, batch, cfg)
    assert out.shape == (batch["x"].shape[0], cfg.n_out)
    _no_nans(out)
    loss, g = jax.value_and_grad(lambda p: gm.loss_fn(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    _no_nans(g)


def test_autoint_smoke():
    spec = configs.get_spec("autoint")
    cfg = spec.reduced
    params = autoint.init(jax.random.PRNGKey(0), cfg)
    batch = next(recsys_batches(16, cfg.n_fields, cfg.vocab_per_field))
    logits = autoint.forward(params, batch, cfg)
    assert logits.shape == (16,)
    _no_nans(logits)
    loss, g = jax.value_and_grad(lambda p: autoint.loss_fn(p, batch, cfg))(
        params
    )
    assert np.isfinite(float(loss))
    _no_nans(g)


def test_registry_covers_all_assigned():
    assert sorted(configs.all_arch_ids()) == sorted(
        LM_ARCHS + GNN_ARCHS + ["autoint"]
    )
    for arch in configs.all_arch_ids():
        spec = configs.get_spec(arch)
        assert len(spec.shapes) == 4  # 4 shape cells per arch = 40 total


def test_full_configs_match_assignment():
    """The published numbers, verbatim."""
    c = configs.get_spec("qwen3-32b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (64, 5120, 64, 8)
    assert (c.d_ff, c.vocab_size, c.qk_norm) == (25600, 151936, True)
    c = configs.get_spec("qwen2.5-32b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (64, 5120, 40, 8)
    assert (c.d_ff, c.vocab_size, c.qkv_bias) == (27648, 152064, True)
    c = configs.get_spec("h2o-danube-1.8b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (24, 2560, 32, 8)
    assert (c.d_ff, c.vocab_size, c.swa_window) == (6912, 32000, 4096)
    c = configs.get_spec("qwen3-moe-235b-a22b").config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (94, 4096, 64, 4)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff_expert) == (128, 8, 1536)
    assert c.vocab_size == 151936
    # ~235B total / ~22B active sanity
    assert 2.0e11 < c.n_params() < 2.7e11, c.n_params()
    assert 1.8e10 < c.n_active_params() < 2.6e10, c.n_active_params()
    c = configs.get_spec("deepseek-moe-16b").config
    assert (c.n_layers, c.d_model, c.n_heads) == (28, 2048, 16)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.n_shared_experts) == (64, 6, 2)
    assert c.vocab_size == 102400
    assert 1.2e10 < c.n_params() < 2.2e10, c.n_params()
    c = configs.get_spec("pna").config
    assert (c.n_layers, c.d_hidden) == (4, 75)
    assert c.pna_aggregators == ("mean", "max", "min", "std")
    c = configs.get_spec("graphsage-reddit").config
    assert (c.n_layers, c.d_hidden, c.aggregator) == (2, 128, "mean")
    assert c.fanouts == (25, 10)
    c = configs.get_spec("graphcast").config
    assert (c.n_layers, c.d_hidden, c.n_out) == (16, 512, 227)
    c = configs.get_spec("gat-cora").config
    assert (c.n_layers, c.d_hidden, c.n_heads) == (2, 8, 8)
    c = configs.get_spec("autoint").config
    assert (c.n_fields, c.embed_dim, c.n_attn_layers) == (39, 16, 3)
    assert (c.n_heads, c.d_attn) == (2, 32)
