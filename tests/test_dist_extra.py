"""Extra distribution-layer coverage beyond ``test_dist.py``:

* property tests that the spec-derivation rules (``_maybe`` /
  ``lm_param_spec`` / ``batch_shardings``-style entries) never emit a
  partition whose mesh-axis product fails divisibility — for randomized
  shapes AND randomized mesh sizes (the rules are pure in ``mesh.shape``,
  so a lightweight mesh stand-in covers sizes no CPU host can build);
* ``constrain`` must round-trip values bit-exactly when deactivated;
* ``mp_edge_softmax`` vs ``edge_softmax`` on the 8-fake-device mesh
  (``test_dist.py`` exercises only gather / segment_reduce).
"""

import subprocess
import sys
import textwrap
from pathlib import Path
from types import SimpleNamespace

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from jax.sharding import PartitionSpec as P

from repro.dist import sharding as shd

REPO = Path(__file__).resolve().parent.parent


@st.composite
def fake_mesh(draw):
    """Mesh stand-in with arbitrary axis sizes (rules read only .shape)."""
    shape = {}
    if draw(st.booleans()):
        shape["pod"] = draw(st.sampled_from([1, 2, 3]))
    shape["data"] = draw(st.sampled_from([1, 2, 3, 4, 5, 8, 16]))
    shape["model"] = draw(st.sampled_from([1, 2, 3, 4, 7, 8, 16]))
    return SimpleNamespace(shape=shape)


@st.composite
def array_shape(draw):
    ndim = draw(st.integers(1, 4))
    return tuple(draw(st.integers(1, 48)) for _ in range(ndim))


def _assert_divisible(spec, shape, mesh):
    __tracebackhide__ = True
    for i, entry in enumerate(spec):
        if entry is None:
            continue
        assert i < len(shape), (spec, shape)
        size = shd.axis_size(entry, mesh)
        assert shape[i] % size == 0, (spec, shape, mesh.shape)


@settings(max_examples=200, deadline=None)
@given(fake_mesh(), array_shape(), st.integers(0, 2**31 - 1))
def test_maybe_never_emits_indivisible_specs(mesh, shape, seed):
    rng = np.random.default_rng(seed)
    candidates = [None, "data", "model", ("data", "model")]
    if "pod" in mesh.shape:
        candidates += ["pod", ("pod", "data")]
    axes = tuple(
        candidates[int(rng.integers(0, len(candidates)))] for _ in shape
    )
    spec = shd._maybe(axes, shape, mesh)
    assert len(tuple(spec)) == min(len(axes), len(shape))
    _assert_divisible(spec, shape, mesh)
    # entries survive untouched when they do divide
    for a, e, dim in zip(axes, tuple(spec), shape):
        if a is not None and all(n in mesh.shape for n in (
            a if isinstance(a, tuple) else (a,)
        )) and dim % shd.axis_size(a, mesh) == 0:
            assert e == a


_LM_PATHS = [
    ("embed", 2),
    ("unembed", 2),
    ("layers/ln1", 2),
    ("layers/wq", 3),
    ("layers/wk", 3),
    ("layers/wv", 3),
    ("layers/wo", 3),
    ("layers/ffn/w1", 3),
    ("layers/ffn/w3", 3),
    ("layers/ffn/w2", 3),
    ("layers/moe/router", 3),
    ("layers/moe/w1", 4),
    ("layers/moe/w2", 4),
    ("layers/moe/w3", 4),
    ("layers/moe/shared/w1", 3),
    ("layers/moe/shared/w2", 3),
]


@settings(max_examples=200, deadline=None)
@given(
    fake_mesh(),
    st.sampled_from(_LM_PATHS),
    st.integers(0, 2**31 - 1),
    st.sampled_from(["fsdp", "zero1"]),
)
def test_lm_param_spec_always_divisible(mesh, path_ndim, seed, mode):
    path, ndim = path_ndim
    rng = np.random.default_rng(seed)
    shape = tuple(int(rng.integers(1, 64)) for _ in range(ndim))
    leaf = SimpleNamespace(shape=shape)
    spec = shd.lm_param_spec(path, leaf, mesh, mode=mode)
    _assert_divisible(spec, shape, mesh)
    if mode == "zero1":  # stored params carry no data-group shards
        for entry in spec:
            names = entry if isinstance(entry, tuple) else (entry,)
            assert "data" not in names and "pod" not in names, spec


def test_known_spec_shapes_on_production_mesh_arithmetic():
    """The policy table from the module docstring, on production-like sizes
    (pure mesh.shape arithmetic — no 512-device host needed)."""
    mesh = SimpleNamespace(shape={"data": 16, "model": 16})
    wq = SimpleNamespace(shape=(64, 5120, 8192))
    assert shd.lm_param_spec("layers/wq", wq, mesh) == P(None, "data", "model")
    assert shd.lm_param_spec("layers/wq", wq, mesh, mode="zero1") == P(
        None, None, "model"
    )
    odd = SimpleNamespace(shape=(64, 5120, 8200))  # 8200 % 16 != 0
    assert shd.lm_param_spec("layers/wq", odd, mesh) == P(None, "data", None)
    router = SimpleNamespace(shape=(64, 5120, 128))
    assert shd.lm_param_spec("layers/moe/router", router, mesh) == P()


def test_constrain_roundtrip_when_deactivated():
    shd.deactivate()
    rng = np.random.default_rng(0)
    for shape, dtype in [((7, 13), np.float32), ((4, 4), np.int32),
                         ((5,), np.float64)]:
        x = jnp.asarray(rng.normal(size=shape).astype(dtype))
        y = shd.constrain(x, (shd.ALL,) + (None,) * (x.ndim - 1))
        assert y is x  # literal no-op, not a copy
        z = shd.constrain(x, (shd.BATCH,) + (None,) * (x.ndim - 1))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(z))


def test_constrain_truncates_overlength_axes():
    """A spec longer than the array rank must truncate, not blow up."""
    from repro.launch.mesh import make_mesh

    m = make_mesh((1, 1), ("data", "model"))
    shd.activate(m)
    try:
        x = jnp.ones((4, 4))
        y = shd.constrain(x, (shd.BATCH, None, None))
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    finally:
        shd.deactivate()


def test_batch_shardings_kinds():
    from repro.launch.mesh import make_mesh

    m = make_mesh((1, 1), ("data", "model"))
    specs = {"x": jax.ShapeDtypeStruct((8, 4), jnp.float32)}
    for kind in ("lm", "gnn", "recsys"):
        s = shd.batch_shardings(kind, specs, m)
        assert s["x"].mesh == m
    try:
        shd.batch_shardings("nope", specs, m)
    except ValueError as e:
        assert "nope" in str(e)
    else:  # pragma: no cover
        raise AssertionError("expected ValueError for unknown kind")


def test_activate_deactivate_roundtrip():
    from repro.launch.mesh import make_mesh

    assert shd.active_mesh() is None
    m = make_mesh((1, 1), ("data", "model"))
    assert shd.activate(m) is m
    assert shd.active_mesh() is m
    assert shd._ACTIVE_MESH is m
    shd.deactivate()
    assert shd.active_mesh() is None
    shd.deactivate()  # idempotent


EDGE_SOFTMAX_SUBPROCESS = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("JAX_PLATFORMS", "cpu")
    import jax, jax.numpy as jnp, numpy as np
    from repro.dist import sharding as shd
    from repro.graph import ops as gops

    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    rng = np.random.default_rng(7)
    n, e = 64, 128  # e divides the 8-way flattened mesh
    dst = jnp.asarray(rng.integers(0, n, e).astype(np.int32))
    scores = jnp.asarray(rng.normal(size=e).astype(np.float32) * 4.0)
    mask = jnp.asarray(rng.random(e) < 0.85)

    ref = gops.edge_softmax(scores, dst, n, mask=mask)
    shd.activate(mesh)
    with mesh:
        mp = jax.jit(
            lambda s, m: gops.mp_edge_softmax(s, dst, n, mask=m)
        )(scores, mask)
        # differentiable end-to-end (max + sum reductions across shards)
        g = jax.jit(jax.grad(lambda s: jnp.sum(
            gops.mp_edge_softmax(s, dst, n, mask=mask) ** 2
        )))(scores)
    shd.deactivate()
    assert np.allclose(np.asarray(mp), np.asarray(ref), atol=1e-6), (
        np.max(np.abs(np.asarray(mp) - np.asarray(ref))))
    # masked edges contribute exactly zero; per-dst masses sum to 1
    sums = gops.segment_reduce(mp, dst, n, "sum", mask=mask)
    s = np.asarray(sums)
    deg = np.zeros(n); np.add.at(deg, np.asarray(dst)[np.asarray(mask)], 1)
    assert np.all((np.abs(s - 1) < 1e-5) | (deg == 0))
    assert np.all(np.asarray(mp)[~np.asarray(mask)] == 0.0)
    assert np.all(np.isfinite(np.asarray(g)))
    print("EDGE_SOFTMAX_OK")
    """
)


@pytest.mark.subprocess_mesh
def test_mp_edge_softmax_multidevice():
    """mp_edge_softmax matches edge_softmax on an 8-fake-device mesh."""
    res = subprocess.run(
        [sys.executable, "-c", EDGE_SOFTMAX_SUBPROCESS],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        timeout=500,
        cwd=str(REPO),
    )
    assert "EDGE_SOFTMAX_OK" in res.stdout, res.stdout + res.stderr
