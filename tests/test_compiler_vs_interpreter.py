"""Dense compiled executor vs the naive per-vertex oracle, plus ground truth.

These are the system's semantic correctness tests: every stdlib algorithm is
run through (a) the dense fused JAX executor, (b) the per-vertex Python
interpreter, on several random graphs, and the results must agree exactly
(bit-equal for ints/bools, allclose for floats). Where an independent ground
truth is cheap (Bellman-Ford, union-find), we check against it too.
"""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import algorithms as alg
from repro.core import compile_program, interpret
from repro.graph import generators as G

FLOAT_FIELDS = {"sssp": ("D",), "pagerank": ("PR",)}


def _agree(out, ref, float_fields):
    for f in out:
        if f.startswith("_"):
            continue
        a, b = np.asarray(out[f]), np.asarray(ref[f])
        if f in float_fields:
            assert np.allclose(a, b, rtol=1e-4, atol=1e-6, equal_nan=True), f
        else:
            assert np.array_equal(a, b), (f, a[:10], np.asarray(b)[:10])


def _run_both(src, g, fields=None, float_fields=()):
    cp = compile_program(src, g, initial_fields=fields)
    out, trips, counts = cp.run(fields)
    ref, rtrips = interpret(src, g, fields)
    assert trips[: len(rtrips)] == rtrips
    _agree(out, ref, float_fields)
    return out, counts


@pytest.mark.parametrize("seed", [0, 1, 2])
class TestAlgorithmsMatchOracle:
    def test_sssp(self, seed):
        g = G.erdos_renyi(50, 4.0, directed=True, weighted=True, seed=seed)
        out, _ = _run_both(alg.SSSP, g, float_fields=("D",))
        # ground truth: Bellman-Ford
        src, dst, w, m = map(
            np.asarray, (g.src, g.dst, g.weight, g.edge_mask)
        )
        dist = np.full(g.n_vertices, math.inf)
        dist[0] = 0.0
        for _ in range(g.n_vertices):
            nd = dist.copy()
            for s, d, ww, mm in zip(src, dst, w, m):
                if mm and dist[s] + ww < nd[d]:
                    nd[d] = dist[s] + ww
            if np.array_equal(nd, dist):
                break
            dist = nd
        assert np.allclose(np.asarray(out["D"]), dist, rtol=1e-4, equal_nan=True)

    def test_sv_connectivity(self, seed):
        g = G.erdos_renyi(50, 3.0, directed=False, seed=seed)
        out, counts = _run_both(alg.SV, g)
        # ground truth: union-find components
        src, dst, m = map(np.asarray, (g.src, g.dst, g.edge_mask))
        parent = list(range(g.n_vertices))

        def find(x):
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for s, d, mm in zip(src, dst, m):
            if mm:
                parent[find(s)] = find(d)
        cc = np.array([find(i) for i in range(g.n_vertices)])
        D = np.asarray(out["D"])
        for i in range(g.n_vertices):
            for j in range(i + 1, g.n_vertices):
                assert (cc[i] == cc[j]) == (D[i] == D[j])
        # the paper's superstep claim: optimized ≪ naive for S-V
        assert counts["palgol_push"] < counts["naive"]
        assert counts["palgol_pull"] <= counts["palgol_push"]

    def test_wcc(self, seed):
        g = G.erdos_renyi(50, 3.0, directed=False, seed=seed)
        _run_both(alg.WCC, g)

    def test_pagerank(self, seed):
        g = G.erdos_renyi(50, 4.0, directed=True, seed=seed)
        out, _ = _run_both(alg.PAGERANK, g, float_fields=("PR",))
        pr = np.asarray(out["PR"])
        assert np.all(pr > 0) and np.all(np.isfinite(pr))

    def test_mis(self, seed):
        g = G.erdos_renyi(50, 4.0, directed=False, seed=seed)
        rng = np.random.default_rng(seed)
        P = jnp.asarray(rng.random(g.n_vertices), jnp.float32)
        out, _ = _run_both(alg.MIS, g, fields={"P": P})
        inm = np.asarray(out["InMIS"])
        src, dst, m = map(np.asarray, (g.src, g.dst, g.edge_mask))
        # independence
        for s, d, mm in zip(src, dst, m):
            if mm:
                assert not (inm[s] and inm[d])
        # maximality
        for v in range(g.n_vertices):
            if not inm[v]:
                nb = src[(dst == v) & m]
                assert len(nb) > 0 and any(inm[u] for u in nb)

    def test_bipartite_matching(self, seed):
        g, side = G.random_bipartite(20, 20, 3.0, seed=seed)
        out, _ = _run_both(
            alg.BIPARTITE_MATCHING, g, fields={"Side": jnp.asarray(side)}
        )
        M = np.asarray(out["M"])
        n = g.n_vertices
        for v in range(n):
            if M[v] < n:
                assert M[M[v]] == v  # matching is symmetric

    def test_mwm(self, seed):
        g = G.erdos_renyi(40, 3.0, directed=False, weighted=True, seed=seed)
        out, _ = _run_both(alg.MWM, g)
        M = np.asarray(out["M"])
        n = g.n_vertices
        for v in range(n):
            if M[v] < n:
                assert M[M[v]] == v

    def test_scc(self, seed):
        g = G.erdos_renyi(40, 3.0, directed=True, seed=seed)
        out, _ = _run_both(alg.SCC, g)

    def test_chain4(self, seed):
        g = G.erdos_renyi(30, 2.0, directed=False, seed=seed)
        rng = np.random.default_rng(seed)
        D = jnp.asarray(rng.integers(0, 30, 30), jnp.int32)
        out, counts = _run_both(alg.CHAIN4, g, fields={"D": D})
        d = np.asarray(D)
        assert np.array_equal(np.asarray(out["D4"]), d[d[d[d]]])
        # paper: 3 message rounds for D⁴ (+1 main superstep)
        assert counts["palgol_push"] == 4
        assert counts["palgol_pull"] == 3  # beyond-paper: pointer doubling
        assert counts["naive"] == 7  # six request/reply rounds + main


class TestHaltingSemantics:
    def test_stopped_vertices_freeze(self):
        src = """
for v in V
    local X[v] := 0
end
stop v in V if Id[v] < 5
for v in V
    local X[v] := 1
end
"""
        g = G.cycle(10)
        cp = compile_program(src, g)
        out, _, _ = cp.run()
        x = np.asarray(out["X"])
        assert np.array_equal(x[:5], np.zeros(5, np.int32))
        assert np.array_equal(x[5:], np.ones(5, np.int32))
        ref, _ = interpret(src, g)
        assert np.array_equal(x, ref["X"])

    def test_stopped_vertices_reject_remote_writes(self):
        src = """
for v in V
    local X[v] := 0
end
stop v in V if Id[v] == 0
for v in V
    remote X[0] += 1
end
"""
        g = G.cycle(6)
        cp = compile_program(src, g)
        out, _, _ = cp.run()
        assert int(out["X"][0]) == 0
        ref, _ = interpret(src, g)
        assert np.array_equal(np.asarray(out["X"]), ref["X"])

    def test_stopped_fields_still_readable(self):
        src = """
for v in V
    local X[v] := Id[v] * 10
end
stop v in V if Id[v] == 0
for v in V
    local Y[v] := X[0]
end
"""
        g = G.cycle(6)
        out, _, _ = compile_program(src, g).run()
        assert np.array_equal(np.asarray(out["Y"]), np.zeros(6, np.int32))
