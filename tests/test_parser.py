"""Palgol parser tests."""

import pytest

from repro.core import ast
from repro.core import algorithms as alg
from repro.core.parser import PalgolSyntaxError, parse


class TestParseStdlib:
    @pytest.mark.parametrize("name", sorted(alg.ALL))
    def test_parses(self, name):
        prog = parse(alg.ALL[name])
        assert isinstance(prog, (ast.Step, ast.Seq, ast.Iter))

    def test_sssp_structure(self):
        prog = parse(alg.SSSP)
        assert isinstance(prog, ast.Seq)
        init, it = prog.progs
        assert isinstance(init, ast.Step)
        assert isinstance(it, ast.Iter)
        assert it.fix_fields == ("D",)

    def test_sv_chain_and_remote(self):
        prog = parse(alg.SV)
        it = prog.progs[1]
        exprs = list(ast.walk_exprs(it))
        # D[D[u]] appears as nested FieldAccess
        nested = [
            e
            for e in exprs
            if isinstance(e, ast.FieldAccess)
            and isinstance(e.index, ast.FieldAccess)
        ]
        assert nested
        stmts = [
            s
            for step in _steps(it)
            for s in ast.walk_stmts(step.body)
            if isinstance(s, ast.RemoteWrite)
        ]
        assert stmts and stmts[0].op == "<?="

    def test_pagerank_fixed_trips(self):
        prog = parse(alg.PAGERANK)
        it = prog.progs[1]
        assert it.fixed_trips == 30
        assert it.fix_fields == ()


def _steps(p):
    if isinstance(p, ast.Step):
        yield p
    elif isinstance(p, ast.Seq):
        for q in p.progs:
            yield from _steps(q)
    elif isinstance(p, ast.Iter):
        yield from _steps(p.body)


class TestSyntaxErrors:
    def test_remote_plain_assign_rejected(self):
        src = """
for v in V
    remote D[Id[v]] := 1
end
"""
        with pytest.raises(PalgolSyntaxError):
            parse(src)

    def test_lowercase_field_rejected(self):
        src = """
for v in V
    local D[v] := d[v]
end
"""
        with pytest.raises(PalgolSyntaxError):
            parse(src)

    def test_comprehension_needs_edge_range(self):
        src = """
for v in V
    let x = sum [1 | e <- D[v]]
end
"""
        with pytest.raises(PalgolSyntaxError):
            parse(src)

    def test_inconsistent_dedent(self):
        src = "for v in V\n    local D[v] := 1\n  local E[v] := 2\nend\n"
        with pytest.raises(PalgolSyntaxError):
            parse(src)

    def test_edge_prop_only_on_vars(self):
        with pytest.raises(PalgolSyntaxError):
            parse("for v in V\n    local D[v] := D[v].id\nend\n")


class TestExpressions:
    def test_precedence(self):
        prog = parse("for v in V\n    local X[v] := 1 + 2 * 3 < 7 && true\nend\n")
        (step,) = list(_steps(prog))
        (w,) = step.body
        # (&& ((1 + (2*3)) < 7) true)
        assert isinstance(w.value, ast.BinOp) and w.value.op == "&&"
        cmp = w.value.left
        assert cmp.op == "<" and cmp.left.op == "+"

    def test_ternary_nesting(self):
        prog = parse(
            "for v in V\n    local X[v] := Id[v] == 0 ? 1 : Id[v] == 1 ? 2 : 3\nend\n"
        )
        (step,) = list(_steps(prog))
        assert isinstance(step.body[0].value, ast.Cond)

    def test_stop_step(self):
        prog = parse("stop v in V if Id[v] == 0\n")
        assert isinstance(prog, ast.StopStep)
