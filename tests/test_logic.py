"""Chain-access logic system tests (paper §4.1.1)."""


from repro.core.logic import (
    PullSolver,
    PushSolver,
    generalize,
    is_subpattern,
    pull_rounds,
    push_rounds,
)


class TestPushSolver:
    def test_axioms_are_free(self):
        assert push_rounds(()) == 0
        assert push_rounds(("D",)) == 0

    def test_d2_request_reply(self):
        # D[D[u]] needs a request and a reply: 2 rounds
        assert push_rounds(("D", "D")) == 2

    def test_d4_paper_example(self):
        # the paper's headline example: D⁴[u] in 3 rounds, not 6
        assert push_rounds(("D",) * 4) == 3

    def test_d4_derivation_matches_figure7(self):
        s = PushSolver()
        plan = s.solve((), ("D",) * 4)
        assert plan.rounds == 3
        assert plan.via == ("D", "D")  # the w = D²[u] intermediate

    def test_mixed_chain(self):
        assert push_rounds(("B", "A")) == 2  # A[B[u]]
        assert push_rounds(("C", "B", "A")) == 3

    def test_monotone_in_depth(self):
        prev = 0
        for k in range(1, 10):
            r = push_rounds(("D",) * k)
            assert r >= prev
            prev = r

    def test_never_worse_than_request_reply(self):
        # naive request/reply costs 2 rounds per hop
        for k in range(2, 9):
            assert push_rounds(("D",) * k) <= 2 * (k - 1)


class TestPullSolver:
    def test_axioms(self):
        assert pull_rounds(()) == 0
        assert pull_rounds(("D",)) == 0

    def test_single_gather(self):
        assert pull_rounds(("D", "D")) == 1
        assert pull_rounds(("B", "A")) == 1

    def test_pointer_doubling(self):
        # ceil(log2 k) for uniform chains
        import math

        for k in range(1, 17):
            assert pull_rounds(("D",) * k) == max(
                0, math.ceil(math.log2(k))
            ), k

    def test_pull_beats_push(self):
        for k in range(2, 9):
            assert pull_rounds(("D",) * k) < push_rounds(("D",) * k)

    def test_schedule_topological(self):
        s = PullSolver()
        order = s.schedule([("D",) * 4, ("D", "D", "A")])
        seen = set()
        for p in order:
            plan = s.solve(p)
            if plan.prefix is not None:
                assert plan.prefix.pattern in seen
                assert plan.suffix.pattern in seen
            seen.add(p)

    def test_schedule_dedups_shared_subchains(self):
        s = PullSolver()
        order = s.schedule([("D",) * 4, ("D",) * 2])
        assert len(order) == len(set(order))
        assert ("D", "D") in order


class TestPatternAlgebra:
    def test_subpattern(self):
        assert is_subpattern((), ("D",))
        assert is_subpattern(("D",), ("D", "D"))
        assert not is_subpattern(("D",), ("D",))
        assert not is_subpattern(("A",), ("D", "A"))

    def test_generalize(self):
        # K_{D[u]} D²[u]  →  K_u D[u]
        assert generalize(("D",), ("D", "D")) == ((), ("D",))
        # K_{D[u]} u cannot be generalized
        assert generalize(("D",), ()) == (("D",), ())
