import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
    )

"""Palgol programs on the production mesh — the paper-technique §Perf cell.

Lowers the S-V connectivity program (the paper's flagship, Fig. 6) against
the 256-chip mesh with vertex/edge arrays sharded over all axes, under two
chain-access schedules:

  naive — request/reply per hop (hand-written-Pregel wire traffic)
  pull  — the logic-system-derived one-sided schedule (this framework)

and records the roofline terms of one fixed-point iteration each. Writes
experiments/palgol_mesh/<algo>_<mode>.json. Shardings come from
``repro.dist`` (the ``ALL`` logical axis via ``batch_shardings``), the same
rules the live models use.

It also writes ``BENCH_palgol_mesh.json`` at the repo root: per-superstep
communicated bytes of the replicated layout vs the partitioned layout
(``repro.graph.partition``), measured on concrete graphs — the scaling
argument for the halo-exchange subsystem in one artifact.

    PYTHONPATH=src python -m benchmarks.palgol_mesh [--scale 22]
    PYTHONPATH=src python -m benchmarks.palgol_mesh --comm-only
"""

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import compile_program
from repro.core import ast as past
from repro.dist import sharding as shd
from repro.graph.structure import Graph
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import HW, collective_bytes_from_hlo, roofline_terms


def abstract_graph(n: int, e: int) -> Graph:
    i32 = jnp.int32
    f32 = jnp.float32
    b = jnp.bool_
    sds = jax.ShapeDtypeStruct
    return Graph(
        src=sds((e,), i32), dst=sds((e,), i32), weight=sds((e,), f32),
        edge_mask=sds((e,), b), t_src=sds((e,), i32), t_dst=sds((e,), i32),
        t_weight=sds((e,), f32), t_mask=sds((e,), b),
        n_vertices=n, n_edges=e,
    )


def one_iteration_prog(prog):
    """The iteration body as a standalone program (per-superstep roofline);
    iteration-free programs (e.g. chain4) are used whole."""
    items = prog.progs if isinstance(prog, past.Seq) else (prog,)
    for p in items:
        if isinstance(p, past.Iter):
            return p.body
    return prog


def run_cell(algo: str, mode: str, n: int, e: int, mesh):
    src = alg.ALL[algo]
    # a tiny concrete graph for field discovery; the mesh lowering uses an
    # abstract same-structure graph of production size
    from repro.graph import generators as G

    small = G.erdos_renyi(64, 4.0, directed=False, weighted=True, seed=0)
    init_fields = None
    if algo == "chain4":
        init_fields = {"D": jnp.zeros((64,), jnp.int32)}
    cp = compile_program(src, small, initial_fields=init_fields, schedule=mode)
    body = one_iteration_prog(cp.prog)
    import dataclasses

    cp_body = dataclasses.replace(
        compile_program(src, small, initial_fields=init_fields, schedule=mode),
        prog=body, n_iters=0,
    )
    ag = abstract_graph(n, e)
    fields = {
        k: jax.ShapeDtypeStruct((n,) + s.shape[1:], s.dtype)
        for k, s in cp.field_struct.items()
    }
    # vertex/edge dims 1-D over the flattened mesh, via the repro.dist rules
    # (ALL logical axis) instead of hand-rolled P(("data","model")) specs
    fshard = shd.batch_shardings("gnn", fields, mesh)
    gshard = shd.batch_shardings("gnn", ag, mesh)

    def step(flds, graph):
        out, _ = cp_body.fn(flds, graph=graph)
        return out

    with mesh:
        lowered = jax.jit(
            step, in_shardings=(fshard, gshard), out_shardings=fshard
        ).lower(fields, ag)
        compiled = lowered.compile()
    # cost_analysis() is a dict on jax ≥ 0.4.38, a one-element list before
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = cost or {}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo, mesh.size)
    mem = compiled.memory_analysis()
    # model flops for one S-V iteration ≈ a few ops per edge + per vertex
    model_flops = 4.0 * e + 8.0 * n
    terms = roofline_terms(
        float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0)),
        coll["total"], mesh.size, HW(), model_flops,
    )
    return {
        "algo": algo,
        "mode": mode,
        "n_vertices": n,
        "n_edges": e,
        "collectives": coll,
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "memory_peak_gb": (
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
        ) / 1e9,
        "roofline": terms,
    }


def comm_comparison(n_shards: int = 8) -> dict:
    """Replicated-vs-partitioned bytes per superstep on concrete graphs.

    Graphs are chosen to span locality regimes: a range-local grid (the
    partitioned layout's best case — halo ≪ N), and an R-MAT power-law
    graph (its worst case — cuts everywhere). Runs host-side (the
    partitioner needs no devices), so it is cheap enough for CI and for
    the partition acceptance test.
    """
    from repro.graph import generators as G
    from repro.graph.partition import comm_bytes_report

    cells = {}
    graphs = {
        "grid_512x8": G.grid2d(512, 8),
        "rmat_s12": G.rmat(12, avg_degree=8.0, directed=True, seed=5),
    }
    for gname, g in graphs.items():
        rep = comm_bytes_report(g, n_shards)
        cells[gname] = rep
    return {
        "n_shards": n_shards,
        "per_graph": cells,
        "note": (
            "bytes per pull superstep for one f32 vertex field, aggregate "
            "across devices; 'padded' is the static-shape all_to_all cost "
            "the implementation actually pays"
        ),
    }


def schedule_report(
    algos=("sssp", "wcc", "sv", "chain4", "pagerank"), n_shards: int = 8
) -> dict:
    """Per-schedule superstep counts and bytes-per-superstep, derived from
    the plan IR (``repro.core.plan``) — the (executor × schedule) cost
    surface in one artifact.

    For each algorithm and each schedule (pull / naive / auto) we lower
    every step to its StepPlan, execute once on a small graph to get real
    trip counts, and report: the per-step op lists, total executed
    supersteps (the STM cost model evaluated on the measured trips — equal
    to what both the staged and the partitioned executor actually charge),
    and the partitioned layout's padded bytes × supersteps per iteration
    on the grid graph (what one fixed-point round costs on the wire).
    """
    from repro.graph import generators as G
    from repro.graph.partition import comm_bytes_report

    grid = G.grid2d(512, 8)
    grid_bytes = comm_bytes_report(grid, n_shards)[
        "partitioned_padded_bytes_per_superstep"
    ]
    small = G.erdos_renyi(64, 4.0, directed=False, weighted=True, seed=0)
    out = {}
    for algo in algos:
        init_fields = None
        if algo == "chain4":
            init_fields = {"D": jnp.zeros((64,), jnp.int32)}
        cp = compile_program(alg.ALL[algo], small, initial_fields=init_fields)
        _, trips, counts = cp.run(init_fields)
        from repro.core.plan import program_plan_records

        cell = {}
        for sched in ("pull", "naive", "auto"):
            key = {"pull": "pull_staged", "naive": "naive", "auto": "auto"}[sched]
            total = counts[key]
            cell[sched] = {
                "steps": program_plan_records(cp.step_plans(sched)),
                "executed_supersteps": total,
                "grid_padded_bytes_total": total * grid_bytes,
            }
        out[algo] = cell
    return {
        "n_shards": n_shards,
        "grid_padded_bytes_per_superstep": grid_bytes,
        "per_algo": out,
        "note": (
            "superstep counts are plan-derived (len(StepPlan.ops) per step, "
            "STM cost model on measured trips); bytes are the grid graph's "
            "partitioned padded per-superstep cost times executed supersteps"
        ),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=26,
                    help="log2 vertices (default 64M vertices, 1B edges)")
    ap.add_argument("--algos", default="sv,wcc")
    ap.add_argument("--comm-only", action="store_true",
                    help="only write BENCH_palgol_mesh.json (no 512-dev "
                         "roofline lowering)")
    ap.add_argument("--shards", type=int, default=8)
    args = ap.parse_args()

    bench = comm_comparison(args.shards)
    bench["schedules"] = schedule_report(n_shards=args.shards)
    repo_root = Path(__file__).resolve().parent.parent
    (repo_root / "BENCH_palgol_mesh.json").write_text(json.dumps(bench, indent=1))
    for algo, cell in bench["schedules"]["per_algo"].items():
        per = {s: cell[s]["executed_supersteps"] for s in cell}
        print(f"{algo}: supersteps {per}", flush=True)
    for gname, rec in bench["per_graph"].items():
        red = rec["reduction_vs_replicated"]
        nph = rec["vertices_per_halo_entry"]
        print(
            f"{gname}: replicated={rec['replicated_bytes_per_superstep']/1e3:.1f}KB "
            f"partitioned(padded)={rec['partitioned_padded_bytes_per_superstep']/1e3:.1f}KB "
            f"reduction={'inf' if red is None else f'{red:.1f}'}x "
            f"N/halo={'inf' if nph is None else f'{nph:.1f}'}",
            flush=True,
        )
    if args.comm_only:
        return

    n = 1 << args.scale
    e = n * 16
    mesh = make_production_mesh()
    out_dir = Path("experiments/palgol_mesh")
    out_dir.mkdir(parents=True, exist_ok=True)
    for algo in args.algos.split(","):
        for mode in ("naive", "pull"):
            rec = run_cell(algo, mode, n, e, mesh)
            p = out_dir / f"{algo}_{mode}.json"
            p.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(
                f"{algo}/{mode}: collective={r['collective_s']*1e3:.2f}ms "
                f"compute={r['compute_s']*1e3:.3f}ms "
                f"memory={r['memory_s']*1e3:.2f}ms "
                f"coll_bytes/dev={rec['collectives']['total']/1e6:.1f}MB "
                f"bottleneck={r['bottleneck']}",
                flush=True,
            )


if __name__ == "__main__":
    main()
