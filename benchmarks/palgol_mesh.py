import os
if "--xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
    )

"""Palgol programs on the production mesh — the paper-technique §Perf cell.

Lowers the S-V connectivity program (the paper's flagship, Fig. 6) against
the 256-chip mesh with vertex/edge arrays sharded over all axes, under two
chain-access schedules:

  naive — request/reply per hop (hand-written-Pregel wire traffic)
  pull  — the logic-system-derived one-sided schedule (this framework)

and records the roofline terms of one fixed-point iteration each. Writes
experiments/palgol_mesh/<algo>_<mode>.json. Shardings come from
``repro.dist`` (the ``ALL`` logical axis via ``batch_shardings``), the same
rules the live models use.

It also writes ``BENCH_palgol_mesh.json`` at the repo root: per-superstep
communicated bytes of the replicated layout vs the partitioned layout
(``repro.graph.partition``), measured on concrete graphs — the scaling
argument for the halo-exchange subsystem in one artifact.

    PYTHONPATH=src python -m benchmarks.palgol_mesh [--scale 22]
    PYTHONPATH=src python -m benchmarks.palgol_mesh --comm-only
"""

import argparse
import dataclasses
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as alg
from repro.core import compile_program
from repro.core import ast as past
from repro.dist import sharding as shd
from repro.graph.structure import Graph
from repro.launch.mesh import make_production_mesh
from repro.roofline.analysis import HW, collective_bytes_from_hlo, roofline_terms


def abstract_graph(n: int, e: int) -> Graph:
    i32 = jnp.int32
    f32 = jnp.float32
    b = jnp.bool_
    sds = jax.ShapeDtypeStruct
    return Graph(
        src=sds((e,), i32), dst=sds((e,), i32), weight=sds((e,), f32),
        edge_mask=sds((e,), b), t_src=sds((e,), i32), t_dst=sds((e,), i32),
        t_weight=sds((e,), f32), t_mask=sds((e,), b),
        n_vertices=n, n_edges=e,
    )


def one_iteration_prog(prog):
    """The iteration body as a standalone program (per-superstep roofline);
    iteration-free programs (e.g. chain4) are used whole."""
    items = prog.progs if isinstance(prog, past.Seq) else (prog,)
    for p in items:
        if isinstance(p, past.Iter):
            return p.body
    return prog


def run_cell(algo: str, mode: str, n: int, e: int, mesh):
    src = alg.ALL[algo]
    # a tiny concrete graph for field discovery; the mesh lowering uses an
    # abstract same-structure graph of production size
    from repro.graph import generators as G

    small = G.erdos_renyi(64, 4.0, directed=False, weighted=True, seed=0)
    init_fields = None
    if algo == "chain4":
        init_fields = {"D": jnp.zeros((64,), jnp.int32)}
    cp = compile_program(src, small, initial_fields=init_fields, schedule=mode)
    body = one_iteration_prog(cp.prog)
    cp_body = dataclasses.replace(
        compile_program(src, small, initial_fields=init_fields, schedule=mode),
        prog=body, n_iters=0,
    )
    ag = abstract_graph(n, e)
    fields = {
        k: jax.ShapeDtypeStruct((n,) + s.shape[1:], s.dtype)
        for k, s in cp.field_struct.items()
    }
    # vertex/edge dims 1-D over the flattened mesh, via the repro.dist rules
    # (ALL logical axis) instead of hand-rolled P(("data","model")) specs
    fshard = shd.batch_shardings("gnn", fields, mesh)
    gshard = shd.batch_shardings("gnn", ag, mesh)

    def step(flds, graph):
        out, _ = cp_body.fn(flds, graph=graph)
        return out

    with mesh:
        lowered = jax.jit(
            step, in_shardings=(fshard, gshard), out_shardings=fshard
        ).lower(fields, ag)
        compiled = lowered.compile()
    # cost_analysis() is a dict on jax ≥ 0.4.38, a one-element list before
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost = cost or {}
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo, mesh.size)
    mem = compiled.memory_analysis()
    # model flops for one S-V iteration ≈ a few ops per edge + per vertex
    model_flops = 4.0 * e + 8.0 * n
    terms = roofline_terms(
        float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0)),
        coll["total"], mesh.size, HW(), model_flops,
    )
    return {
        "algo": algo,
        "mode": mode,
        "n_vertices": n,
        "n_edges": e,
        "collectives": coll,
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "memory_peak_gb": (
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
        ) / 1e9,
        "roofline": terms,
    }


def comm_comparison(n_shards: int = 8) -> dict:
    """Replicated-vs-partitioned bytes per superstep on concrete graphs.

    Graphs are chosen to span locality regimes: a range-local grid (the
    partitioned layout's best case — halo ≪ N), and an R-MAT power-law
    graph (its worst case — cuts everywhere). Runs host-side (the
    partitioner needs no devices), so it is cheap enough for CI and for
    the partition acceptance test.
    """
    from repro.graph import generators as G
    from repro.graph.partition import comm_bytes_report

    cells = {}
    graphs = {
        "grid_512x8": G.grid2d(512, 8),
        "rmat_s12": G.rmat(12, avg_degree=8.0, directed=True, seed=5),
    }
    for gname, g in graphs.items():
        rep = comm_bytes_report(g, n_shards)
        cells[gname] = rep
    return {
        "n_shards": n_shards,
        "per_graph": cells,
        "note": (
            "bytes per pull superstep for one f32 vertex field, aggregate "
            "across devices; 'padded' is the static-shape all_to_all cost "
            "the implementation actually pays"
        ),
    }


#: schedule → STM cost-model key for the UNFUSED expansion (what
#: ``run_bsp(..., fuse=False)`` executes)
SCHED_KEYS = {
    "pull": "pull_staged",
    "push": "push",
    "naive": "naive",
    "auto": "auto",
}

#: schedule → STM cost-model key for the §4.3-FUSED plan (state merging +
#: iteration fusion — what every executor dispatches by default)
FUSED_KEYS = {
    "pull": "fused_pull",
    "push": "fused_push",
    "naive": "fused_naive",
    "auto": "fused_auto",
}


def schedule_report(
    algos=("sssp", "wcc", "sv", "chain4", "pagerank"),
    n_shards: int = 8,
    grid_shape=(512, 8),
) -> dict:
    """Per-schedule superstep counts and modeled bytes, derived from the
    plan IR (``repro.core.plan``) — the (executor × schedule) cost surface
    in one artifact.

    For each algorithm and each schedule (pull / push / naive / auto) we
    lower every step to its StepPlan, execute once on a small graph to get
    real trip counts, and report: the per-step op lists with their
    byte-model estimates, total executed supersteps (the STM cost model
    evaluated on the measured trips — equal to what every executor
    actually charges), and the partitioned layout's padded bytes ×
    supersteps per iteration on the grid graph.

    Each schedule cell reports both the unfused (``fuse=False``) and the
    §4.3-fused (default execution) superstep totals — the
    ``bench-plan-regression`` gate diffs both, so neither the per-step
    expansion nor the program-level fuse pass can drift silently. Each
    algo cell also records the measured per-iteration fixed-point frontier
    (``active_set_per_iter``, from a staged ``run_bsp`` — the live
    request-set figure ``ByteCostModel.request_set`` models) and, for the
    chain-access programs, the measured request-dedup savings of
    ``gather_global``'s unique pass (``gather_dedup``).

    ``auto_byte_regimes`` shows where the byte-aware selector flips: under
    the *dense* regime (every vertex reads its chain — pull's best case)
    and the *sparse* regime (request set = the grid halo, combined further
    by message dedup — deep chains with tiny frontiers), per step. The
    regime cost models always derive from the canonical 512×8 grid (its
    host-side partition costs milliseconds), so the selections the
    ``bench-plan-regression`` gate diffs are identical between ``--quick``
    runs and the committed full-size report; ``grid_shape`` only scales
    the padded-byte figures, which the gate deliberately ignores.
    """
    from repro.core.plan import program_plan_records
    from repro.graph import generators as G
    from repro.graph.partition import (
        byte_cost_model,
        comm_bytes_report,
        request_dedup_report,
    )
    from repro.pregel import run_bsp

    grid = G.grid2d(*grid_shape)
    grid_rep = comm_bytes_report(grid, n_shards)
    grid_bytes = grid_rep["partitioned_padded_bytes_per_superstep"]
    small = G.erdos_renyi(64, 4.0, directed=False, weighted=True, seed=0)
    # the two byte regimes the selector is judged under — pinned to the
    # canonical grid so they are graph-size-invariant across --quick
    regime_grid = G.grid2d(512, 8)
    halo_total = comm_bytes_report(regime_grid, n_shards)["partition"][
        "halo_total"
    ]
    dense_costs = byte_cost_model(regime_grid, n_shards)
    sparse_costs = byte_cost_model(
        regime_grid,
        n_shards,
        request_set=max(1, halo_total),
        combined_request_set=max(1, halo_total // 4),
    )
    out = {}
    for algo in algos:
        init_fields = None
        if algo == "chain4":
            # a random indirection field: makes the chain request sets (and
            # the dedup measurement below) non-degenerate; plan-derived
            # counts are structural, so the regression gate is unaffected
            rng = np.random.default_rng(0)
            init_fields = {"D": jnp.asarray(rng.integers(0, 64, 64), jnp.int32)}
        cp = compile_program(alg.ALL[algo], small, initial_fields=init_fields)
        dense_out, trips, counts = cp.run(init_fields)
        staged = run_bsp(
            cp.prog, small, cp.init_fields(init_fields), schedule="pull"
        )

        cell = {
            # measured fixed-point frontier per loop entry, per iteration —
            # the live request-set instrumentation replacing the supplied
            # ByteCostModel.request_set constant
            "active_set_per_iter": staged.active_sets,
        }
        # measured request-dedup savings of gather_global's unique pass on
        # the programs' real indirection fields (the chain request sets)
        if algo == "sv":
            cell["gather_dedup"] = request_dedup_report(
                dense_out["D"], small.n_vertices
            )
        elif algo == "chain4":
            cell["gather_dedup"] = request_dedup_report(
                init_fields["D"], small.n_vertices
            )
        for sched, key in SCHED_KEYS.items():
            total = counts[key]
            fused_total = counts[FUSED_KEYS[sched]]
            cell[sched] = {
                "steps": program_plan_records(
                    cp.step_plans(sched), costs=dense_costs
                ),
                "executed_supersteps": total,
                "fused_supersteps": fused_total,
                "grid_padded_bytes_total": total * grid_bytes,
                "grid_padded_bytes_total_fused": fused_total * grid_bytes,
            }
        cell["auto_byte_regimes"] = {
            regime: [
                r["resolved"]
                for r in program_plan_records(
                    dataclasses.replace(cp, byte_costs=costs).step_plans(
                        "auto"
                    ),
                    costs=costs,
                )
            ]
            for regime, costs in (
                ("dense", dense_costs), ("sparse", sparse_costs),
            )
        }
        out[algo] = cell
    return {
        "n_shards": n_shards,
        "grid_padded_bytes_per_superstep": grid_bytes,
        "sparse_regime": {
            "request_set": max(1, halo_total),
            "combined_request_set": max(1, halo_total // 4),
        },
        "per_algo": out,
        "note": (
            "superstep counts are plan-derived (STM cost models on "
            "measured trips): 'executed_supersteps' is the unfused per-op "
            "expansion (fuse=False), 'fused_supersteps' the §4.3-fused "
            "plan every executor dispatches by default; per-step 'bytes' "
            "is the plan byte model under the dense regime; bytes totals "
            "are the grid graph's partitioned padded per-superstep cost "
            "times supersteps"
        ),
    }


def check_plan_regression(bench: dict, committed_path: Path) -> list:
    """Diff plan-derived superstep counts per (program × schedule) —
    unfused AND fused — against the committed benchmark JSON. Returns a
    list of drift descriptions (empty = clean). Byte figures and the
    measured frontier/dedup cells are deliberately NOT compared — they
    scale with the grid, which ``--quick`` shrinks; the plan-derived
    counts and resolved schedules must be graph-size-invariant.
    """
    committed = json.loads(committed_path.read_text())
    drifts = []
    old_algos = committed.get("schedules", {}).get("per_algo", {})
    new_algos = bench["schedules"]["per_algo"]
    for algo in sorted(set(old_algos) | set(new_algos)):
        if algo not in old_algos or algo not in new_algos:
            drifts.append(f"{algo}: present in only one report")
            continue
        for sched in SCHED_KEYS:
            old, new = old_algos[algo].get(sched), new_algos[algo].get(sched)
            if old is None or new is None:
                drifts.append(f"{algo}/{sched}: present in only one report")
                continue
            for fld in ("executed_supersteps", "fused_supersteps"):
                if old.get(fld) != new.get(fld):
                    drifts.append(
                        f"{algo}/{sched}: {fld} {old.get(fld)} -> "
                        f"{new.get(fld)}"
                    )
            old_steps = [
                (s["resolved"], s["supersteps"]) for s in old["steps"]
            ]
            new_steps = [
                (s["resolved"], s["supersteps"]) for s in new["steps"]
            ]
            if old_steps != new_steps:
                drifts.append(
                    f"{algo}/{sched}: per-step plans {old_steps} -> {new_steps}"
                )
        for regime in ("dense", "sparse"):
            old = old_algos[algo].get("auto_byte_regimes", {}).get(regime)
            new = new_algos[algo].get("auto_byte_regimes", {}).get(regime)
            if old != new:
                drifts.append(
                    f"{algo}/auto[{regime}]: resolved {old} -> {new}"
                )
    return drifts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=26,
                    help="log2 vertices (default 64M vertices, 1B edges)")
    ap.add_argument("--algos", default="sv,wcc")
    ap.add_argument("--comm-only", action="store_true",
                    help="only write BENCH_palgol_mesh.json (no 512-dev "
                         "roofline lowering)")
    ap.add_argument("--quick", action="store_true",
                    help="CI mode: tiny grid, comm+schedule report only — "
                         "plan-derived counts are identical to the full run")
    ap.add_argument("--out", default=None,
                    help="where to write the benchmark JSON (default: "
                         "repo-root BENCH_palgol_mesh.json)")
    ap.add_argument("--check", default=None, metavar="COMMITTED_JSON",
                    help="diff plan-derived superstep counts per (program "
                         "× schedule) against a committed report; exit 2 "
                         "on drift (the bench-plan-regression CI gate)")
    ap.add_argument("--shards", type=int, default=8)
    args = ap.parse_args()

    grid_shape = (64, 8) if args.quick else (512, 8)
    bench = comm_comparison(args.shards)
    bench["schedules"] = schedule_report(
        n_shards=args.shards, grid_shape=grid_shape
    )
    repo_root = Path(__file__).resolve().parent.parent
    out_path = (
        Path(args.out) if args.out else repo_root / "BENCH_palgol_mesh.json"
    )
    out_path.write_text(json.dumps(bench, indent=1))
    for algo, cell in bench["schedules"]["per_algo"].items():
        per = {
            s: f"{cell[s]['fused_supersteps']}/{cell[s]['executed_supersteps']}"
            for s in SCHED_KEYS
            if s in cell
        }
        print(f"{algo}: supersteps fused/unfused {per} "
              f"auto_bytes={cell['auto_byte_regimes']}", flush=True)
        if "gather_dedup" in cell:
            d = cell["gather_dedup"]
            print(
                f"  gather dedup: {d['raw_request_slots']} -> "
                f"{d['deduped_request_slots']} slots "
                f"({d['raw_bytes']} -> {d['deduped_bytes']} B)",
                flush=True,
            )
    if args.check:
        drifts = check_plan_regression(bench, Path(args.check))
        if drifts:
            print("PLAN REGRESSION: plan-derived counts drifted from "
                  f"{args.check}:", flush=True)
            for d in drifts:
                print(f"  {d}", flush=True)
            sys.exit(2)
        print(f"plan-regression check vs {args.check}: clean", flush=True)
    for gname, rec in bench["per_graph"].items():
        red = rec["reduction_vs_replicated"]
        nph = rec["vertices_per_halo_entry"]
        print(
            f"{gname}: replicated={rec['replicated_bytes_per_superstep']/1e3:.1f}KB "
            f"partitioned(padded)={rec['partitioned_padded_bytes_per_superstep']/1e3:.1f}KB "
            f"reduction={'inf' if red is None else f'{red:.1f}'}x "
            f"N/halo={'inf' if nph is None else f'{nph:.1f}'}",
            flush=True,
        )
    if args.comm_only or args.quick:
        return

    n = 1 << args.scale
    e = n * 16
    mesh = make_production_mesh()
    out_dir = Path("experiments/palgol_mesh")
    out_dir.mkdir(parents=True, exist_ok=True)
    for algo in args.algos.split(","):
        for mode in ("naive", "pull"):
            rec = run_cell(algo, mode, n, e, mesh)
            p = out_dir / f"{algo}_{mode}.json"
            p.write_text(json.dumps(rec, indent=1))
            r = rec["roofline"]
            print(
                f"{algo}/{mode}: collective={r['collective_s']*1e3:.2f}ms "
                f"compute={r['compute_s']*1e3:.3f}ms "
                f"memory={r['memory_s']*1e3:.2f}ms "
                f"coll_bytes/dev={rec['collectives']['total']/1e6:.1f}MB "
                f"bottleneck={r['bottleneck']}",
                flush=True,
            )


if __name__ == "__main__":
    main()
