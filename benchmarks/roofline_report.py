"""Roofline summary rows from the dry-run artifacts (§Roofline source)."""

from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks.common import row


def load_records(mesh: str = "single"):
    recs = []
    for f in sorted(glob.glob(f"experiments/dryrun/{mesh}/*.json")):
        recs.append(json.loads(Path(f).read_text()))
    return recs


def run():
    out = []
    recs = load_records("single")
    if not recs:
        out.append(row("roofline/missing", 0,
                       "run launch/dryrun.py first"))
        return out
    worst = None
    for r in recs:
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        name = f"roofline/{r['arch']}/{r['shape']}"
        out.append(row(
            name,
            ro["step_lower_bound_s"] * 1e6,
            f"bottleneck={ro['bottleneck']};frac={ro.get('roofline_fraction', 0):.4f}"
            f";fits={r['memory']['fits_16GB']}",
        ))
        frac = ro.get("roofline_fraction", 0)
        if worst is None or frac < worst[1]:
            worst = (name, frac)
    ok = sum(1 for r in recs if r["status"] == "ok")
    sk = sum(1 for r in recs if r["status"] == "skipped")
    out.append(row("roofline/summary", 0, f"ok={ok};skipped={sk}"))
    return out
