"""Generate the §Dry-run and §Roofline sections of EXPERIMENTS.md from the
dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.gen_experiments > experiments/roofline_sections.md
"""

from __future__ import annotations

import glob
import json
from pathlib import Path


def fmt_bytes(b):
    return f"{b/1e9:.2f}"


def load(mesh):
    recs = {}
    for f in sorted(glob.glob(f"experiments/dryrun/{mesh}/*.json")):
        r = json.loads(Path(f).read_text())
        recs[(r["arch"], r["shape"])] = r
    return recs


def main():
    single = load("single")
    multi = load("multi")
    print("## §Dry-run — 40 cells × 2 meshes (16×16 single-pod; 2×16×16 multi-pod)\n")
    print("Status per cell (`ok` = lower+compile succeeded; bytes = peak per "
          "device from `memory_analysis()`; target chip = TPU v5e, 16 GB):\n")
    print("| arch | shape | single: status / GB / fits | multi: status / GB / fits | compile s (single) |")
    print("|---|---|---|---|---|")
    n_ok = n_skip = n_fail = 0
    for key in sorted(single):
        s, m = single[key], multi.get(key, {})
        def cell(r):
            if not r:
                return "—"
            if r["status"] == "skipped":
                return "skip (justified)"
            if r["status"] == "failed":
                return "FAILED"
            mem = r["memory"]
            fits = mem["fits_16GB"]
            out = (f"ok / {fmt_bytes(mem['peak_per_device_bytes'])} / "
                   f"{'✓' if fits else '✗'}")
            if not fits and "peak_tpu_corrected_bytes" in mem:
                out += (f" (TPU-corr {fmt_bytes(mem['peak_tpu_corrected_bytes'])}"
                        f" {'✓' if mem['fits_16GB_corrected'] else '✗'})")
            return out
        for r in (s, m):
            if r:
                n_ok += r["status"] == "ok"
                n_skip += r["status"] == "skipped"
                n_fail += r["status"] == "failed"
        comp = s.get("compile_s", "—") if s.get("status") == "ok" else "—"
        print(f"| {key[0]} | {key[1]} | {cell(s)} | {cell(m)} | {comp} |")
    print(f"\nTotals: ok={n_ok}, skipped={n_skip} (long_500k × 4 full-attention "
          f"archs, per harness rule), failed={n_fail}.\n")

    print("\n## §Roofline — single-pod (256 × v5e: 197 TF/s bf16, 819 GB/s "
          "HBM, 50 GB/s ICI)\n")
    print("Terms in **seconds per step** from the compiled dry-run; "
          "`useful` = MODEL_FLOPS / HLO_FLOPS; `frac` = roofline fraction "
          "(useful model flops per second ÷ peak at the step lower bound).\n")
    print("| arch | shape | compute s | memory s | collective s | bottleneck "
          "| useful | frac | note |")
    print("|---|---|---|---|---|---|---|---|---|")
    for key in sorted(single):
        r = single[key]
        if r["status"] != "ok":
            continue
        ro = r["roofline"]
        note = ""
        mf = ro.get("model_flops", 0)
        print(
            f"| {key[0]} | {key[1]} | {ro['compute_s']:.4f} | "
            f"{ro['memory_s']:.4f} | {ro['collective_s']:.4f} | "
            f"{ro['bottleneck'].replace('_s','')} | "
            f"{ro.get('useful_flops_ratio', 0):.2f} | "
            f"{ro.get('roofline_fraction', 0):.4f} | {note} |"
        )


if __name__ == "__main__":
    main()
