"""Paper Table 4 analogue: execution time, Palgol-compiled vs manual-style.

The paper compares compiler-generated Pregel+ code against hand-written
implementations (−25.9% … +32.4%). Our analogue on one host:

* ``palgol``  — the dense compiled program: ONE fused XLA computation
  (state merging + iteration fusion taken to their limit on a
  shared-address-space machine); termination check fused into the
  while-loop (the compiled aggregator).
* ``manual``  — the staged BSP executor with the *naive* schedule:
  one device dispatch per superstep, request/reply chain reads, host-side
  aggregator round-trip per iteration — the execution shape of typical
  hand-written Pregel code.

Same runtime, same graph, same results (asserted) — the measured gap is
the cost of superstep structure, which is exactly what the paper's
compiler optimizes.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import row, time_fn
from repro.core import algorithms as alg
from repro.core import compile_program
from repro.graph import generators as G
from repro.pregel import run_bsp


def run(scale: int = 10):
    out = []
    gu = G.rmat(scale, avg_degree=8, directed=False, seed=1)
    gd = G.rmat(scale, avg_degree=8, directed=True, weighted=True, seed=2)
    cases = [
        ("sv", alg.SV, gu, None),
        ("sssp", alg.SSSP, gd, None),
        ("pagerank", alg.PAGERANK, gd, None),
    ]
    for name, src, g, fields in cases:
        cp = compile_program(src, g, initial_fields=fields)
        f0 = cp.init_fields(fields)

        import jax

        fused = jax.jit(cp.fn)
        us_palgol = time_fn(fused, f0, warmup=1, iters=3)
        dense_out, _ = fused(f0)

        def manual(f0=f0, prog=cp.prog, g=g):
            # the manual baseline has no §4.3 merging/fusion: fuse=False
            return run_bsp(prog, g, f0, schedule="naive", fuse=False).fields

        # run_bsp jits per-stage internally; warm indirectly via one call
        import time as _t

        manual_out = manual()
        times = []
        for _ in range(3):
            t0 = _t.perf_counter()
            manual(), (_t.perf_counter() - t0)
            times.append(_t.perf_counter() - t0)
        us_manual = sorted(times)[1] * 1e6

        # same results (float fields compared loosely)
        for fkey in dense_out:
            a = np.asarray(dense_out[fkey])
            b = np.asarray(manual_out[fkey])
            if a.dtype.kind == "f":
                assert np.allclose(a, b, rtol=1e-4, atol=1e-5, equal_nan=True)
            else:
                assert np.array_equal(a, b), fkey

        ratio = us_manual / max(us_palgol, 1e-9)
        out.append(row(f"table4/{name}/palgol", us_palgol,
                       f"speedup_vs_manual={ratio:.2f}x"))
        out.append(row(f"table4/{name}/manual", us_manual, ""))
    return out
