"""Paper Table 5 analogue: superstep counts, Palgol-compiled vs manual.

The paper's headline: S-V drops 51.7%/46.5% supersteps vs hand-written
Pregel+ code; PR is equal; SSSP pays +1 (aggregator vs vote-to-halt).
We reproduce the *structure* of that table on synthetic graphs matching
each algorithm's applicability, under three compilers:
  palgol_push — the paper's compiler (logic-system chains, merging, fusion)
  palgol_pull — this framework's one-sided schedule (beyond-paper)
  naive       — request/reply chains, no merging/fusion (manual baseline)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.core import algorithms as alg
from repro.core import compile_program
from repro.graph import generators as G


def cases(scale: int = 10):
    rng = np.random.default_rng(0)
    gu = G.rmat(scale, avg_degree=8, directed=False, seed=1)
    gd = G.rmat(scale, avg_degree=8, directed=True, weighted=True, seed=2)
    n = gu.n_vertices
    yield "sv", alg.SV, gu, None
    yield "sssp", alg.SSSP, gd, None
    yield "pagerank", alg.PAGERANK, gd, None
    yield "wcc", alg.WCC, gu, None
    yield "mis", alg.MIS, gu, {
        "P": jnp.asarray(rng.random(n), jnp.float32)
    }
    yield "mwm", alg.MWM, G.rmat(scale, 6, directed=False, weighted=True,
                                 seed=3), None


def run(scale: int = 10):
    out = []
    for name, src, g, fields in cases(scale):
        cp = compile_program(src, g, initial_fields=fields)
        _, trips, counts = cp.run(fields)
        push, pull, naive = (
            counts["palgol_push"], counts["palgol_pull"], counts["naive"]
        )
        red_push = 100 * (1 - push / naive)
        red_pull = 100 * (1 - pull / naive)
        out.append(row(
            f"table5/{name}/palgol_push", 0,
            f"supersteps={push};reduction_vs_naive={red_push:.1f}%",
        ))
        out.append(row(
            f"table5/{name}/palgol_pull", 0,
            f"supersteps={pull};reduction_vs_naive={red_pull:.1f}%",
        ))
        out.append(row(f"table5/{name}/naive", 0, f"supersteps={naive}"))
        out.append(row(
            f"table5/{name}/iterations", 0, f"trips={trips[0] if trips else 0}"
        ))
    return out
