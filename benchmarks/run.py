"""Benchmark harness — one section per paper table + kernel/roofline rows.

    PYTHONPATH=src python -m benchmarks.run [--scale 10]

Prints ``name,us_per_call,derived`` CSV:
  table4/*   — execution time, Palgol-compiled vs manual-style (paper Tab.4)
  table5/*   — superstep counts under the three compilers (paper Tab.5)
  kernels/*  — substrate hot-path timings (XLA fallbacks the Pallas kernels
               replace; kernels themselves validate in interpret mode)
  roofline/* — per-cell dry-run roofline terms (from experiments/dryrun)
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10,
                    help="log2 graph size for table4/5 (default 2^10)")
    ap.add_argument("--sections", default="table5,table4,kernels,roofline")
    args = ap.parse_args()
    sections = set(args.sections.split(","))

    print("name,us_per_call,derived")
    rows = []
    if "table5" in sections:
        from benchmarks import table5_supersteps

        rows += table5_supersteps.run(args.scale)
        _flush(rows)
    if "table4" in sections:
        from benchmarks import table4_exec_time

        rows += table4_exec_time.run(args.scale)
        _flush(rows)
    if "kernels" in sections:
        from benchmarks import bench_kernels

        rows += bench_kernels.run()
        _flush(rows)
    if "roofline" in sections:
        from benchmarks import roofline_report

        rows += roofline_report.run()
        _flush(rows)


_printed = 0


def _flush(rows):
    global _printed
    for r in rows[_printed:]:
        print(r, flush=True)
    _printed = len(rows)


if __name__ == "__main__":
    main()
