"""Benchmark timing utilities."""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time per call in microseconds (blocking on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
