"""Kernel-adjacent benchmarks (CPU-host measurable).

Pallas kernels only *validate* on CPU (interpret mode ≈ Python loop — not a
perf number). What we CAN measure here and carry to the roofline story:

* the XLA fallback implementations the kernels replace (segment_sum
  scatter, gather+reduce embedding bag, chunked attention),
* the Palgol substrate ops at graph sizes matching the paper's datasets
  (scaled to one host).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.graph import generators as G
from repro.graph import ops as gops
from repro.models.transformer import attention as att


def run():
    out = []
    rng = np.random.default_rng(0)

    # segment-sum (the Pregel combiner hot path) at increasing edge counts
    for scale, d in [(12, 32), (14, 32), (14, 128)]:
        g = G.rmat(scale, avg_degree=16, seed=1)
        vals = jnp.asarray(
            rng.normal(size=(g.n_edges, d)).astype(np.float32)
        )
        fn = jax.jit(
            lambda v, g=g: gops.segment_reduce(
                v, g.dst, g.n_vertices, "sum", indices_are_sorted=True,
                mask=g.edge_mask,
            )
        )
        us = time_fn(fn, vals)
        gbps = g.n_edges * d * 4 / (us / 1e6) / 1e9
        out.append(row(
            f"kernels/segment_sum/E{g.n_edges}_D{d}", us, f"GB/s={gbps:.2f}"
        ))

    # chunked (flash-style) vs dense attention, fwd
    for s in (512, 1024):
        q = jnp.asarray(rng.normal(size=(1, s, 8, 64)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(1, s, 4, 64)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(1, s, 4, 64)).astype(np.float32))
        pos = jnp.arange(s)
        dense = jax.jit(
            lambda q, k, v: att.attention_dense(q, k, v, pos, pos, True)
        )
        chunk = jax.jit(
            lambda q, k, v: att.attention_chunked(
                q, k, v, pos, pos, True, chunk_kv=256
            )
        )
        us_d = time_fn(dense, q, k, v)
        us_c = time_fn(chunk, q, k, v)
        out.append(row(f"kernels/attention_dense/S{s}", us_d, ""))
        out.append(row(
            f"kernels/attention_flash/S{s}", us_c,
            f"vs_dense={us_d / max(us_c, 1e-9):.2f}x",
        ))

    # embedding bag (take+sum fallback) at recsys sizes
    table = jnp.asarray(rng.normal(size=(100_000, 16)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 100_000, (4096, 39)).astype(np.int32))
    from repro.models.recsys.embedding import embedding_bag

    bag = jax.jit(lambda t, i: embedding_bag(t, i))
    us = time_fn(bag, table, idx)
    out.append(row("kernels/embedding_bag/B4096_F39", us, ""))
    return out
