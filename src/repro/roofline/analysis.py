"""Roofline-term derivation from compiled dry-run artifacts.

Hardware model (TPU v5e-class, per chip):
    peak bf16 compute 197 TFLOP/s, HBM bw 819 GB/s, ICI ~50 GB/s/link.

Terms (seconds per step), using the convention that ``cost_analysis()`` of
the SPMD-partitioned module reports **per-device** flops/bytes:

    compute    = flops_per_device / PEAK_FLOPS
    memory     = hbm_bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / LINK_BW

``collective_bytes_per_device`` is parsed from the post-partitioning HLO:
for each collective instruction we charge the per-chip wire traffic of the
standard ring algorithm —

    all-gather       ≈ output_bytes × (n-1)/n   (receives the other shards)
    reduce-scatter   ≈ input_bytes  × (n-1)/n
    all-reduce       ≈ 2 × input_bytes × (n-1)/n  (RS + AG phases)
    all-to-all       ≈ input_bytes × (n-1)/n
    collective-permute ≈ input_bytes

(n = participating devices per replica group, parsed from the instruction).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # bytes/s
    link_bw: float = 50e9  # bytes/s/link (ICI)
    hbm_bytes: float = 16e9  # v5e capacity


_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_ARR_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_ARR_RE.search(line)
    if m:  # replica_groups=[G,S] → S devices per group
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def collective_bytes_from_hlo(hlo_text: str, n_devices: int) -> Dict[str, float]:
    """Per-device wire bytes per collective kind, from post-SPMD HLO text."""
    # symbol table: instr name -> output bytes
    sizes: Dict[str, int] = {}
    per_kind: Dict[str, float] = {k: 0.0 for k in COLLECTIVE_OPS}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, type_str, op = m.groups()
        sizes[name] = shape_bytes(type_str)
    for ln in lines:
        m = _INSTR_RE.match(ln)
        if not m:
            continue
        name, type_str, op = m.groups()
        kind = None
        for k in COLLECTIVE_OPS:
            # count the op (or its async -start form); -done is the same
            # transfer completing, so counting it would double the bytes
            if op == k or op == k + "-start":
                kind = k
                break
        if kind is None:
            continue
        out_bytes = sizes[name]
        n = _group_size(ln, n_devices)
        frac = (n - 1) / max(n, 1)
        if kind == "all-gather":
            per_kind[kind] += out_bytes * frac
        elif kind == "all-reduce":
            per_kind[kind] += 2 * out_bytes * frac
        elif kind == "reduce-scatter":
            per_kind[kind] += out_bytes * (n - 1)  # input = out × n
        elif kind == "all-to-all":
            per_kind[kind] += out_bytes * frac
        else:  # collective-permute
            per_kind[kind] += out_bytes
    per_kind["total"] = sum(v for k, v in per_kind.items() if k != "total")
    return per_kind


def roofline_terms(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_bytes_per_device: float,
    n_devices: int,
    hw: Optional[HW] = None,
    model_flops: Optional[float] = None,
) -> Dict[str, float]:
    hw = hw or HW()
    compute = flops_per_device / hw.peak_flops
    memory = hbm_bytes_per_device / hw.hbm_bw
    collective = collective_bytes_per_device / hw.link_bw
    terms = {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "bottleneck": max(
            ("compute_s", compute),
            ("memory_s", memory),
            ("collective_s", collective),
            key=lambda kv: kv[1],
        )[0],
        "step_lower_bound_s": max(compute, memory, collective),
    }
    if model_flops is not None:
        total_hlo = flops_per_device * n_devices
        terms["model_flops"] = model_flops
        terms["useful_flops_ratio"] = model_flops / total_hlo if total_hlo else 0.0
        # roofline fraction: useful model flops per second vs peak
        denom = terms["step_lower_bound_s"] * n_devices * hw.peak_flops
        terms["roofline_fraction"] = model_flops / denom if denom else 0.0
    return terms
