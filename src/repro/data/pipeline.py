"""Synthetic data pipelines (deterministic, shard-aware).

Production deployments replace these generators with storage readers; the
iterator contract (yield pytrees matching ``input_specs``) and the host→device
sharded placement stay the same. Each generator is seeded and cheap enough
to run on the host while the previous step executes (software pipelining —
the input-pipeline half of compute/IO overlap).
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph import generators as G
from repro.graph.sampler import CSR, sample_khop


def _put(tree, shardings=None):
    if shardings is None:
        return tree
    return jax.device_put(tree, shardings)


def token_batches(
    batch: int,
    seq_len: int,
    vocab: int,
    seed: int = 0,
    shardings=None,
) -> Iterator[dict]:
    """LM batches: next-token labels over a synthetic Zipf token stream."""
    rng = np.random.default_rng(seed)
    while True:
        # Zipf-ish distribution to give the embedding gather realistic skew
        z = rng.zipf(1.3, size=(batch, seq_len + 1)) % vocab
        toks = z.astype(np.int32)
        yield _put(
            {
                "tokens": jnp.asarray(toks[:, :-1]),
                "labels": jnp.asarray(toks[:, 1:]),
            },
            shardings,
        )


def recsys_batches(
    batch: int,
    n_fields: int,
    vocab: int,
    seed: int = 0,
    shardings=None,
) -> Iterator[dict]:
    rng = np.random.default_rng(seed)
    while True:
        fields = rng.zipf(1.2, size=(batch, n_fields)) % vocab
        # synthetic CTR signal: depends on a few field hashes
        logit = ((fields[:, 0] + fields[:, 1]) % 7 - 3) * 0.7
        labels = (rng.random(batch) < 1 / (1 + np.exp(-logit))).astype(np.float32)
        yield _put(
            {
                "fields": jnp.asarray(fields.astype(np.int32)),
                "labels": jnp.asarray(labels),
            },
            shardings,
        )


def gnn_full_batch(
    n_nodes: int,
    avg_degree: float,
    d_feat: int,
    n_classes: int,
    seed: int = 0,
    task: str = "node_class",
    n_out: int = 0,
    shardings=None,
) -> dict:
    """One full-graph batch from an RMAT generator."""
    import math

    g = G.rmat(
        max(2, int(math.ceil(math.log2(max(n_nodes, 2))))),
        avg_degree=avg_degree,
        directed=False,
        seed=seed,
    )
    rng = np.random.default_rng(seed)
    n = g.n_vertices
    batch = {
        "x": jnp.asarray(rng.normal(size=(n, d_feat)).astype(np.float32)),
        "src": g.src,
        "dst": g.dst,
        "emask": g.edge_mask,
    }
    if task == "regression":
        batch["labels"] = jnp.asarray(
            rng.normal(size=(n, n_out)).astype(np.float32)
        )
        batch["lmask"] = jnp.ones((n,), jnp.float32)
    else:
        batch["labels"] = jnp.asarray(
            rng.integers(0, n_classes, size=n).astype(np.int32)
        )
        batch["lmask"] = jnp.asarray(
            (rng.random(n) < 0.5).astype(np.float32)
        )
    return _put(batch, shardings)


def gnn_minibatches(
    graph,
    features: jax.Array,
    labels: jax.Array,
    batch_nodes: int,
    fanouts,
    seed: int = 0,
    shardings=None,
) -> Iterator[dict]:
    """Sampled GraphSAGE minibatches using the real neighbor sampler."""
    csr = CSR.from_graph(graph)
    key = jax.random.PRNGKey(seed)
    n = graph.n_vertices
    sentinel_feat = jnp.zeros((1, features.shape[1]), features.dtype)
    feats_ext = jnp.concatenate([features, sentinel_feat], axis=0)
    while True:
        key, k1, k2 = jax.random.split(key, 3)
        seeds = jax.random.randint(k1, (batch_nodes,), 0, n)
        blocks = sample_khop(csr, seeds, fanouts, k2)
        b0, b1 = blocks
        yield _put(
            {
                "seed_x": jnp.take(feats_ext, seeds, axis=0),
                "hop0_x": jnp.take(
                    feats_ext, b0.neighbors.reshape(-1), axis=0
                ),
                "hop0_mask": b0.mask,
                "hop1_x": jnp.take(
                    feats_ext, b1.neighbors.reshape(-1), axis=0
                ),
                "hop1_mask": b1.mask,
                "labels": jnp.take(labels, seeds, axis=0),
            },
            shardings,
        )
