from repro.data.pipeline import (
    token_batches,
    recsys_batches,
    gnn_full_batch,
    gnn_minibatches,
)

__all__ = [
    "token_batches",
    "recsys_batches",
    "gnn_full_batch",
    "gnn_minibatches",
]
