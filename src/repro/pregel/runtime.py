"""Staged BSP executor: one device dispatch per Pregel superstep.

Execution model (mirrors paper Fig. 9 + §4.3): the whole Palgol program is
lowered by :func:`repro.core.plan.lower_program` to a
:class:`~repro.core.plan.ProgramPlan` and — by default — rewritten by
:func:`repro.core.plan.fuse` (state merging + iteration fusion, §4.3).
This runtime dispatches **one jitted device call per fused superstep**: a
merged superstep's parts (e.g. the previous step's RemoteUpdate plus the
next step's first ReadRound, or a fused loop's main compute plus the next
iteration's prefetched ReadRound) execute inside one dispatch, threading a
program-level mailbox (chain/neighborhood buffers, pending remote-write
payloads) between dispatches. ``fuse=False`` keeps the historical per-op
expansion — same results, more supersteps.

* ``schedule="pull"`` plans chain reads by the PullSolver gather DAG
  (this framework's optimized one-sided schedule);
* ``schedule="push"`` runs the paper-faithful message schedule: address
  flows forward along the chain while values double back; each
  ``push_request`` op combines requester ids per owner (Pregel message
  combining — a segment-combine scatter), each ``push_reply`` op ships one
  combined reply per distinct owner and materializes its chain buffers;
* ``schedule="naive"`` emulates the hand-written request/reply style: every
  chain hop costs a *request* superstep (push requester ids to the owner —
  a real scatter, matching the message traffic of manual Pregel code) and a
  *reply* superstep (the owner sends the value back — a gather);
* ``schedule="auto"`` picks the cheapest plan per step (by op count, or by
  the byte model when ``byte_costs`` is given);
* fixed-point termination is checked on host between supersteps, exactly like
  Pregel's aggregator round-trip; the per-iteration frontier size (how many
  vertices' fix fields changed) is recorded in ``BSPResult.active_sets`` —
  the live request-set instrumentation the byte cost model feeds on.

The executed-superstep count is returned and cross-checked in tests against
the STM cost models of ``repro.core.stm`` — both count the same fused plan.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import ast
from repro.core import plan as plan_mod
from repro.core.codegen import HALTED, StepExecutor, _RemoteMsg, make_stop_fn
from repro.core.plan import (
    ByteCostModel,
    ReadRound,
    RemoteUpdate,
    StepPlan,
    lower_step,
)
from repro.graph import ops as gops


@dataclasses.dataclass
class BSPResult:
    fields: Dict[str, jax.Array]
    supersteps: int
    trips: List[int]
    # per loop entry, per iteration: number of vertices whose fix fields
    # changed that iteration (the fixed-point frontier — the measured
    # request-set size ByteCostModel.request_set models)
    active_sets: List[List[int]] = dataclasses.field(default_factory=list)


class _StagedStep:
    """One Palgol step: its :class:`StepPlan` compiled to per-op superstep
    callables ``(fields, mailbox) -> (fields, mailbox)``; ``ns`` prefixes
    this step's mailbox keys so supersteps from different steps can share
    the program-level mailbox of the fused plan.

    This path deliberately does NOT reuse
    :func:`repro.core.codegen.exec_plan_part` (the dense/partitioned
    consumer): the staged dispatches additionally emulate the *wire
    traffic* of each round in their lowered HLO — the naive ``:req``
    requester scatters and the push combined-request buffers — which the
    fused dense trace intentionally omits (its ``push_request`` op is
    compute-free). The replicated mailbox keys here are therefore a
    superset of codegen's; keep the two protocols in sync when adding op
    kinds or buffer classes.
    """

    def __init__(
        self,
        step: ast.Step,
        graph,
        schedule: str,
        byte_costs: Optional[ByteCostModel] = None,
        plan: Optional[StepPlan] = None,
        ns: str = "",
    ):
        self.step = step
        self.graph = graph
        self.plan = (
            plan
            if plan is not None
            else lower_step(step, schedule=schedule, byte_costs=byte_costs)
        )
        self.info = self.plan.info
        # resolved (auto → pull/push/naive)
        self.schedule = self.plan.schedule
        self.ns = ns

    # -- mailbox keys ---------------------------------------------------------
    def _key(self, pattern) -> str:
        return self.ns + "chain:" + "/".join(pattern)

    def _pkey(self, pattern) -> str:
        return self.ns + "pushaddr:" + "/".join(pattern)

    def _nkey(self, direction, pattern) -> str:
        return f"{self.ns}nbr:{direction}:" + "/".join(pattern)

    # -- read supersteps -----------------------------------------------------
    def read_stage_fns(self):
        """List of jitted ``(fields, mailbox) -> mailbox`` functions; one
        per ReadRound op of the plan (the accounting-mirror API)."""
        return [
            jax.jit(self._stage_fn(op))
            for op in self.plan.ops
            if isinstance(op, ReadRound)
        ]

    def _combine_requests(self, owner, combine: str):
        """Requester-id scatter by owner — the request-superstep wire
        traffic. ``combine="set"`` is the naive per-requester buffer
        (colliding requesters overwrite: no combining, as manual code);
        ``combine="min"`` is Pregel message combining (one deterministic
        slot per distinct owner). ``n_vertices`` is the empty sentinel."""
        ids = jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
        reqbuf = jnp.full_like(ids, self.graph.n_vertices)
        if combine == "set":
            return reqbuf.at[owner].set(ids, mode="drop")
        return reqbuf.at[owner].min(ids, mode="drop")

    def _stage_fn(self, op: ReadRound):
        if op.kind == "request":

            def request(fields, mailbox, _op=op):
                # requester u pushes its id to the owner vertex (real
                # scatter: the message traffic manual Pregel code pays)
                out = dict(mailbox)
                for ce in _op.chains:
                    owner = self._lookup(fields, out, ce.prefix)
                    out[self._key(ce.pattern) + ":req"] = (
                        self._combine_requests(owner, "set")
                    )
                return out

            return request

        if op.kind == "push_request":

            def push_request(fields, mailbox, _op=op):
                # address-propagation round: requester ids move one hop
                # along the chain, message-combined per owner (one slot
                # per distinct owner — the scatter-min IS the combiner)
                out = dict(mailbox)
                for send in _op.sends:
                    owner = self._resolve(fields, out, send.target)
                    if owner is None:
                        continue
                    out[self._pkey(send.target) + ":req"] = (
                        self._combine_requests(owner, _op.combiner or "min")
                    )
                return out

            return push_request

        def stage(fields, mailbox, _op=op):
            # "pull": one gather-DAG round; "reply": the owner returns its
            # value to the requester; "push_reply": one combined reply per
            # distinct owner, fanned out to its requesters (the gather),
            # with the request set segment-combined per owner;
            # "nbr_send": per-edge buffers
            out = dict(mailbox)
            for ce in _op.chains:
                pre = self._lookup(fields, out, ce.prefix)
                suf = self._lookup(fields, out, ce.suffix)
                val = gops.gather(suf, pre)
                if _op.kind == "push_reply":
                    # combine concurrent requests per owner (Pregel message
                    # combining; the combiner op is plan-recorded) and fold
                    # the combined buffer into the reply — the term is
                    # exactly zero, but the simplifier can't prove it, so
                    # the combining scatter survives into the lowering
                    reqbuf = self._combine_requests(
                        pre, _op.combiner or "min"
                    )
                    val = val + (
                        gops.gather(reqbuf, pre) // (self.graph.n_vertices + 2)
                    ).astype(val.dtype)
                out[self._key(ce.pattern)] = val
                out.pop(self._key(ce.pattern) + ":req", None)
            if _op.kind == "push_reply":
                # the paired push_request's address buffers were the wire
                # accounting of *their* superstep; done — drop them so
                # later dispatches stop threading dead device buffers
                prefix = self.ns + "pushaddr:"
                for k in [k for k in out if k.startswith(prefix)]:
                    out.pop(k)
            for direction, npat in _op.nbr_sends:
                nbr, _, _, _ = self.graph.edges(direction)
                val = self._lookup(fields, out, npat)
                out[self._nkey(direction, npat)] = gops.gather(val, nbr)
            return out

        return stage

    def _resolve(self, fields, mailbox, pattern):
        """Pattern value if materialized/axiomatic, else None (push address
        flows may target chains materialized later the same round)."""
        if len(pattern) <= 1 or self._key(pattern) in mailbox:
            return self._lookup(fields, mailbox, pattern)
        return None

    def _lookup(self, fields, mailbox, pattern):
        if len(pattern) == 0:
            return jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
        if len(pattern) == 1:
            if pattern[0] == "Id":
                return jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
            return fields[pattern[0]]
        return mailbox[self._key(pattern)]

    # -- per-op superstep callables -------------------------------------------
    def op_fn(self, op):
        """``(fields, mailbox) -> (fields, mailbox)`` for one plan op — the
        building block the per-superstep dispatcher composes (a fused
        superstep is several of these sequenced inside one jit)."""
        if isinstance(op, ReadRound):
            stage = self._stage_fn(op)

            def read(fields, mailbox):
                return fields, stage(fields, mailbox)

            return read
        if isinstance(op, RemoteUpdate):
            return self._update_fn(op)
        return self._main_fn()

    def _main_fn(self):
        has_ru = self.plan.has_remote_update
        materialized = self.plan.materialized
        pending_key = self.ns + "pending"

        def main(fields, mailbox):
            chain_values = {
                p: mailbox[self._key(p)]
                for p in materialized
                if self._key(p) in mailbox
            }
            nbr_values = {
                (d, p): mailbox[self._nkey(d, p)]
                for d, p in self.info.nbr_comms
                if self._nkey(d, p) in mailbox
            }
            # the step's read buffers are consumed here; drop them so the
            # mailbox keyset is loop-stable (fused bodies re-create the
            # prefetched entries at iteration end)
            out = {
                k: v for k, v in mailbox.items()
                if not k.startswith(self.ns)
            }
            ex = StepExecutor(self.step, self.graph, plan=self.plan)
            if has_ru:
                new, pending = ex(
                    fields, chain_values, split_remote=True,
                    nbr_values=nbr_values,
                )
                out[pending_key] = tuple(
                    (m.idx, m.values, m.mask) for m in pending
                )
                return new, out
            return ex(fields, chain_values, nbr_values=nbr_values), out

        return main

    def _update_fn(self, ru: RemoteUpdate):
        pending_key = self.ns + "pending"

        def update(fields, mailbox):
            out = dict(mailbox)
            payload = out.pop(pending_key)
            ex = StepExecutor(self.step, self.graph, plan=self.plan)
            msgs = [
                _RemoteMsg(f, op, idx, val, mask)
                for (f, op), (idx, val, mask) in zip(ru.writes, payload)
            ]
            return ex.apply_remote(fields, msgs), out

        return update

def read_superstep_count(step: ast.Step, schedule: str) -> int:
    """Number of remote-reading supersteps a step costs under ``schedule``
    — ``lower_step(step).read_rounds``, the same plan every executor
    dispatches, so placements cannot diverge from the accounting."""
    return lower_step(step, schedule=schedule).read_rounds


def _frontier_size(before, after, fix_fields, vertex_ndim: int) -> int:
    """Vertices whose fix fields changed this iteration (the fixed-point
    frontier). ``vertex_ndim`` is the number of leading per-vertex dims
    (1 dense, 2 for ``[shard, row]``-blocked partitioned state)."""
    changed = None
    for f in fix_fields:
        d = after[f] != before[f]
        if d.ndim > vertex_ndim:
            d = d.reshape(d.shape[:vertex_ndim] + (-1,)).any(axis=-1)
        changed = d if changed is None else jnp.logical_or(changed, d)
    return int(jnp.sum(changed))


def walk_plan(
    pp: plan_mod.ProgramPlan,
    fields,
    exec_superstep,
    counter: List[int],
    trips: List[int],
    max_iters: int,
    active_sets: Optional[List[List[int]]] = None,
    vertex_ndim: int = 1,
):
    """Host-side walk of a (fused) program plan, shared by every placement.

    ``exec_superstep(superstep, fields)`` executes ONE plan superstep
    (fused parts included) and returns the new fields; this walker owns
    sequencing, trip counting, the host-side OR-aggregator fixed-point
    check, the superstep counter (one per dispatched superstep — the fused
    accounting), and the per-iteration frontier instrumentation — so
    iteration semantics cannot diverge between the replicated and
    partitioned executors.
    """

    def run(items, flds):
        for it in items:
            if isinstance(it, plan_mod.Superstep):
                flds = exec_superstep(it, flds)
                counter[0] += 1
                continue
            # PlanLoop
            trips.append(0)
            slot = len(trips) - 1
            if active_sets is not None:
                active_sets.append([])
            node = it.node
            limit = (
                node.fixed_trips
                if node.fixed_trips is not None
                else max_iters
            )
            for _ in range(limit):
                before = {f: flds[f] for f in node.fix_fields}
                flds = run(it.body, flds)
                trips[slot] += 1
                if node.fix_fields:
                    # host-side aggregator round-trip (Pregel OR-aggregator)
                    frontier = _frontier_size(
                        before, flds, node.fix_fields, vertex_ndim
                    )
                    if active_sets is not None:
                        active_sets[slot].append(frontier)
                    if frontier == 0:
                        break
        return flds

    return run(pp.items, fields)


def run_bsp(
    prog: ast.Prog,
    graph,
    fields: Dict[str, jax.Array],
    schedule: str = "pull",
    max_iters: int = 100_000,
    placement: str = "replicated",
    mesh=None,
    n_shards: Optional[int] = None,
    byte_costs: Optional[ByteCostModel] = None,
    fuse: bool = True,
) -> BSPResult:
    """Execute a Palgol program superstep-by-superstep.

    ``fields`` must be the full canonical field dict (use
    ``CompiledProgram.init_fields``). Returns final fields, the number of
    actually executed supersteps, per-iteration trip counts, and the
    per-iteration fixed-point frontier sizes.

    ``schedule`` ∈ {"pull", "push", "naive", "auto"} selects the
    chain-access lowering (see :mod:`repro.core.plan`) and applies to both
    placements; ``byte_costs`` makes ``"auto"`` select on the byte model.

    ``fuse`` (default True) executes the §4.3-fused program plan — merged
    supersteps dispatch as ONE device call, iteration-fused loops save one
    superstep per iteration; ``fuse=False`` dispatches the unfused per-op
    expansion (bit-identical results, the historical superstep counts).

    ``placement`` selects the vertex-state layout:

    * ``"replicated"`` (default) — dense single-address-space arrays; under
      an active mesh GSPMD/shard_map keep vertex state replicated per chip;
    * ``"partitioned"`` — edge-balanced contiguous-range shards with halo
      exchange (``repro.graph.partition``): each superstep moves only
      boundary state. ``mesh`` (a 1-D ``("shard",)`` mesh) or ``n_shards``
      selects the layout; defaults to one shard per local device. Fields
      are partitioned on entry and returned dense, so callers are
      placement-agnostic.
    """
    if placement == "partitioned":
        from repro.graph.partition import run_bsp_partitioned

        return run_bsp_partitioned(
            prog, graph, fields, schedule=schedule, max_iters=max_iters,
            mesh=mesh, n_shards=n_shards, byte_costs=byte_costs, fuse=fuse,
        )
    if placement != "replicated":
        raise ValueError(f"unknown placement {placement!r}")
    pp = plan_mod.lower_program(prog, schedule=schedule, byte_costs=byte_costs)
    if fuse:
        pp = plan_mod.fuse(pp)

    counter = [0]
    trips: List[int] = []
    active_sets: List[List[int]] = []
    # caches: one _StagedStep per step, one compiled dispatch per Superstep
    # — supersteps re-execute across iterations without re-tracing (as a
    # real Pregel binary would)
    staged: Dict[int, _StagedStep] = {}
    ss_fns: Dict[int, object] = {}
    mailbox_box = [{}]

    def staged_for(ref: plan_mod.OpRef) -> _StagedStep:
        if ref.sidx not in staged:
            staged[ref.sidx] = _StagedStep(
                ref.plan.step, graph, schedule,
                plan=ref.plan, ns=f"s{ref.sidx}:",
            )
        return staged[ref.sidx]

    def build_ss_fn(ss: plan_mod.Superstep):
        part_fns = []
        for ref in ss.parts:
            op = ref.op
            if isinstance(op, plan_mod.IterInit):
                continue
            if isinstance(op, plan_mod.StopOp):
                stop = make_stop_fn(op.stop, graph)
                part_fns.append(lambda f, m, _s=stop: (_s(f), m))
            else:
                part_fns.append(staged_for(ref).op_fn(op))

        def ss_fn(flds, mailbox):
            for fn in part_fns:
                flds, mailbox = fn(flds, mailbox)
            return flds, mailbox

        return jax.jit(ss_fn)

    def exec_superstep(ss: plan_mod.Superstep, flds):
        if id(ss) not in ss_fns:
            ss_fns[id(ss)] = build_ss_fn(ss)
        flds, mailbox_box[0] = ss_fns[id(ss)](flds, mailbox_box[0])
        return flds

    fields = {k: jnp.asarray(v) for k, v in fields.items()}
    if HALTED not in fields:
        fields[HALTED] = jnp.zeros((graph.n_vertices,), jnp.bool_)
    out = walk_plan(
        pp, fields, exec_superstep, counter, trips, max_iters,
        active_sets=active_sets,
    )
    return BSPResult(
        fields=out, supersteps=counter[0], trips=trips,
        active_sets=active_sets,
    )
