"""Staged BSP executor: one device dispatch per Pregel superstep.

Execution model (mirrors paper Fig. 9): each Palgol step is lowered by
:func:`repro.core.plan.lower_step` to a :class:`~repro.core.plan.StepPlan`
— remote-reading supersteps, a main superstep, a remote-updating superstep
— and this runtime dispatches **one jitted device call per plan op**:

* ``schedule="pull"`` plans chain reads by the PullSolver gather DAG
  (this framework's optimized one-sided schedule);
* ``schedule="push"`` runs the paper-faithful message schedule: address
  flows forward along the chain while values double back; each
  ``push_request`` op combines requester ids per owner (Pregel message
  combining — a segment-combine scatter), each ``push_reply`` op ships one
  combined reply per distinct owner and materializes its chain buffers;
* ``schedule="naive"`` emulates the hand-written request/reply style: every
  chain hop costs a *request* superstep (push requester ids to the owner —
  a real scatter, matching the message traffic of manual Pregel code) and a
  *reply* superstep (the owner sends the value back — a gather);
* ``schedule="auto"`` picks the cheapest plan per step (by op count, or by
  the byte model when ``byte_costs`` is given);
* fixed-point termination is checked on host between supersteps, exactly like
  Pregel's aggregator round-trip.

The executed-superstep count is returned and cross-checked in tests against
the STM cost models of ``repro.core.stm`` — both count the same plan ops.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core import ast
from repro.core.codegen import HALTED, StepExecutor, make_stop_fn
from repro.core.plan import ByteCostModel, ReadRound, RemoteUpdate, lower_step
from repro.graph import ops as gops


@dataclasses.dataclass
class BSPResult:
    fields: Dict[str, jax.Array]
    supersteps: int
    trips: List[int]


class _StagedStep:
    """One Palgol step: its :class:`StepPlan` compiled to a list of
    superstep callables — one jitted device dispatch per plan op."""

    def __init__(
        self,
        step: ast.Step,
        graph,
        schedule: str,
        byte_costs: Optional[ByteCostModel] = None,
    ):
        self.step = step
        self.graph = graph
        self.plan = lower_step(step, schedule=schedule, byte_costs=byte_costs)
        self.info = self.plan.info
        # resolved (auto → pull/push/naive)
        self.schedule = self.plan.schedule

    # -- read supersteps -----------------------------------------------------
    def read_stage_fns(self):
        """List of jitted ``(fields, mailbox) -> mailbox`` functions; one
        per ReadRound op of the plan."""
        return [
            self._stage_fn(op)
            for op in self.plan.ops
            if isinstance(op, ReadRound)
        ]

    def _combine_requests(self, owner, combine: str):
        """Requester-id scatter by owner — the request-superstep wire
        traffic. ``combine="set"`` is the naive per-requester buffer
        (colliding requesters overwrite: no combining, as manual code);
        ``combine="min"`` is Pregel message combining (one deterministic
        slot per distinct owner). ``n_vertices`` is the empty sentinel."""
        ids = jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
        reqbuf = jnp.full_like(ids, self.graph.n_vertices)
        if combine == "set":
            return reqbuf.at[owner].set(ids, mode="drop")
        return reqbuf.at[owner].min(ids, mode="drop")

    def _stage_fn(self, op: ReadRound):
        if op.kind == "request":

            def request(fields, mailbox, _op=op):
                # requester u pushes its id to the owner vertex (real
                # scatter: the message traffic manual Pregel code pays)
                out = dict(mailbox)
                for ce in _op.chains:
                    owner = self._lookup(fields, out, ce.prefix)
                    out[_key(ce.pattern) + ":req"] = self._combine_requests(
                        owner, "set"
                    )
                return out

            return jax.jit(request)

        if op.kind == "push_request":

            def push_request(fields, mailbox, _op=op):
                # address-propagation round: requester ids move one hop
                # along the chain, message-combined per owner (one slot
                # per distinct owner — the scatter-min IS the combiner)
                out = dict(mailbox)
                for send in _op.sends:
                    owner = self._resolve(fields, out, send.target)
                    if owner is None:
                        continue
                    out[_pkey(send.target) + ":req"] = self._combine_requests(
                        owner, _op.combiner or "min"
                    )
                return out

            return jax.jit(push_request)

        def stage(fields, mailbox, _op=op):
            # "pull": one gather-DAG round; "reply": the owner returns its
            # value to the requester; "push_reply": one combined reply per
            # distinct owner, fanned out to its requesters (the gather),
            # with the request set segment-combined per owner;
            # "nbr_send": per-edge buffers
            out = dict(mailbox)
            for ce in _op.chains:
                pre = self._lookup(fields, out, ce.prefix)
                suf = self._lookup(fields, out, ce.suffix)
                val = gops.gather(suf, pre)
                if _op.kind == "push_reply":
                    # combine concurrent requests per owner (Pregel message
                    # combining; the combiner op is plan-recorded) and fold
                    # the combined buffer into the reply — the term is
                    # exactly zero, but the simplifier can't prove it, so
                    # the combining scatter survives into the lowering
                    reqbuf = self._combine_requests(
                        pre, _op.combiner or "min"
                    )
                    val = val + (
                        gops.gather(reqbuf, pre) // (self.graph.n_vertices + 2)
                    ).astype(val.dtype)
                out[_key(ce.pattern)] = val
                out.pop(_key(ce.pattern) + ":req", None)
            if _op.kind == "push_reply":
                # the paired push_request's address buffers were the wire
                # accounting of *their* superstep; done — drop them so
                # later dispatches stop threading dead device buffers
                for k in [k for k in out if k.startswith("pushaddr:")]:
                    out.pop(k)
            for direction, npat in _op.nbr_sends:
                nbr, _, _, _ = self.graph.edges(direction)
                val = self._lookup(fields, out, npat)
                out[_nkey(direction, npat)] = gops.gather(val, nbr)
            return out

        return jax.jit(stage)

    def _resolve(self, fields, mailbox, pattern):
        """Pattern value if materialized/axiomatic, else None (push address
        flows may target chains materialized later the same round)."""
        if len(pattern) <= 1 or _key(pattern) in mailbox:
            return self._lookup(fields, mailbox, pattern)
        return None

    def _lookup(self, fields, mailbox, pattern):
        if len(pattern) == 0:
            return jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
        if len(pattern) == 1:
            if pattern[0] == "Id":
                return jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
            return fields[pattern[0]]
        return mailbox[_key(pattern)]

    # -- main + update supersteps ---------------------------------------------
    def main_fn(self):
        has_ru = self.plan.has_remote_update
        materialized = self.plan.materialized

        def main(fields, mailbox):
            chain_values = {
                p: mailbox[_key(p)] for p in materialized if _key(p) in mailbox
            }
            nbr_values = {
                (d, p): mailbox[_nkey(d, p)]
                for d, p in self.info.nbr_comms
                if _nkey(d, p) in mailbox
            }
            ex = StepExecutor(self.step, self.graph, plan=self.plan)
            if has_ru:
                new, pending = ex(
                    fields, chain_values, split_remote=True, nbr_values=nbr_values
                )
                payload = [(m.idx, m.values, m.mask) for m in pending]
                return new, payload
            return ex(fields, chain_values, nbr_values=nbr_values), []

        return jax.jit(main)

    def update_fn(self):
        ru = next(
            op for op in self.plan.ops if isinstance(op, RemoteUpdate)
        )

        def update(fields, payload):
            ex = StepExecutor(self.step, self.graph, plan=self.plan)
            from repro.core.codegen import _RemoteMsg

            msgs = [
                _RemoteMsg(f, op, idx, val, mask)
                for (f, op), (idx, val, mask) in zip(ru.writes, payload)
            ]
            return ex.apply_remote(fields, msgs)

        return jax.jit(update)


def read_superstep_count(step: ast.Step, schedule: str) -> int:
    """Number of remote-reading supersteps a step costs under ``schedule``
    — ``lower_step(step).read_rounds``, the same plan every executor
    dispatches, so placements cannot diverge from the accounting."""
    return lower_step(step, schedule=schedule).read_rounds


def _key(pattern) -> str:
    return "chain:" + "/".join(pattern)


def _pkey(pattern) -> str:
    return "pushaddr:" + "/".join(pattern)


def _nkey(direction, pattern) -> str:
    return f"nbr:{direction}:" + "/".join(pattern)


def walk_program(
    prog: ast.Prog,
    fields,
    exec_step,
    exec_stop,
    counter: List[int],
    trips: List[int],
    max_iters: int,
):
    """Host-side superstep walk shared by every placement.

    ``exec_step(step, fields)`` / ``exec_stop(stop, fields)`` execute one
    Step / StopStep (and account their own supersteps in ``counter``); this
    walker owns sequencing, the iteration Init superstep (paper Fig. 11),
    trip counting, and the host-side OR-aggregator fixed-point check — so
    iteration semantics cannot diverge between the replicated and
    partitioned executors.
    """

    def run(p, flds):
        if isinstance(p, ast.Step):
            return exec_step(p, flds)
        if isinstance(p, ast.StopStep):
            return exec_stop(p, flds)
        if isinstance(p, ast.Seq):
            for q in p.progs:
                flds = run(q, flds)
            return flds
        if isinstance(p, ast.Iter):
            # the iteration Init superstep: sets up the OR-aggregator so
            # the first termination check succeeds
            counter[0] += 1
            trips.append(0)
            slot = len(trips) - 1
            limit = p.fixed_trips if p.fixed_trips is not None else max_iters
            for _ in range(limit):
                before = {f: flds[f] for f in p.fix_fields}
                flds = run(p.body, flds)
                trips[slot] += 1
                if p.fix_fields:
                    # host-side aggregator round-trip (Pregel OR-aggregator)
                    changed = any(
                        bool(jnp.any(flds[f] != before[f]))
                        for f in p.fix_fields
                    )
                    if not changed:
                        break
            return flds
        raise TypeError(type(p))

    return run(prog, fields)


def run_bsp(
    prog: ast.Prog,
    graph,
    fields: Dict[str, jax.Array],
    schedule: str = "pull",
    max_iters: int = 100_000,
    placement: str = "replicated",
    mesh=None,
    n_shards: Optional[int] = None,
    byte_costs: Optional[ByteCostModel] = None,
) -> BSPResult:
    """Execute a Palgol program superstep-by-superstep.

    ``fields`` must be the full canonical field dict (use
    ``CompiledProgram.init_fields``). Returns final fields, the number of
    actually executed supersteps, and per-iteration trip counts.

    ``schedule`` ∈ {"pull", "push", "naive", "auto"} selects the
    chain-access lowering (see :mod:`repro.core.plan`) and applies to both
    placements; ``byte_costs`` makes ``"auto"`` select on the byte model.

    ``placement`` selects the vertex-state layout:

    * ``"replicated"`` (default) — dense single-address-space arrays; under
      an active mesh GSPMD/shard_map keep vertex state replicated per chip;
    * ``"partitioned"`` — edge-balanced contiguous-range shards with halo
      exchange (``repro.graph.partition``): each superstep moves only
      boundary state. ``mesh`` (a 1-D ``("shard",)`` mesh) or ``n_shards``
      selects the layout; defaults to one shard per local device. Fields
      are partitioned on entry and returned dense, so callers are
      placement-agnostic.
    """
    if placement == "partitioned":
        from repro.graph.partition import run_bsp_partitioned

        return run_bsp_partitioned(
            prog, graph, fields, schedule=schedule, max_iters=max_iters,
            mesh=mesh, n_shards=n_shards, byte_costs=byte_costs,
        )
    if placement != "replicated":
        raise ValueError(f"unknown placement {placement!r}")
    counter = [0]
    trips: List[int] = []
    # cache compiled stage functions per Step/StopStep node: supersteps
    # re-execute across iterations without re-tracing (as a real Pregel
    # binary would)
    cache: Dict[int, object] = {}

    def exec_step(step: ast.Step, flds):
        if id(step) not in cache:
            staged = _StagedStep(step, graph, schedule, byte_costs=byte_costs)
            cache[id(step)] = (
                staged,
                staged.read_stage_fns(),
                staged.main_fn(),
                staged.update_fn() if staged.plan.has_remote_update else None,
            )
        staged, read_fns, main_fn, update_fn = cache[id(step)]
        mailbox: Dict[str, jax.Array] = {}
        for stage in read_fns:
            mailbox = stage(flds, mailbox)
            counter[0] += 1
        new, payload = main_fn(flds, mailbox)
        counter[0] += 1
        if update_fn is not None:
            new = update_fn(new, payload)
            counter[0] += 1
        return new

    def exec_stop(stop: ast.StopStep, flds):
        if id(stop) not in cache:
            cache[id(stop)] = jax.jit(make_stop_fn(stop, graph))
        counter[0] += 1
        return cache[id(stop)](flds)

    fields = {k: jnp.asarray(v) for k, v in fields.items()}
    if HALTED not in fields:
        fields[HALTED] = jnp.zeros((graph.n_vertices,), jnp.bool_)
    out = walk_program(
        prog, fields, exec_step, exec_stop, counter, trips, max_iters
    )
    return BSPResult(fields=out, supersteps=counter[0], trips=trips)
