"""Staged BSP executor: one device dispatch per Pregel superstep.

Execution model (mirrors paper Fig. 9):

* each Palgol step expands into: remote-reading supersteps (materializing
  chain-access buffers round by round), a main superstep (local computation +
  emitting remote-write messages), and a remote-updating superstep;
* ``schedule="pull"`` stages chain reads by the PullSolver gather DAG
  (this framework's optimized one-sided schedule);
* ``schedule="naive"`` emulates the hand-written request/reply style: every
  chain hop costs a *request* superstep (push requester ids to the owner —
  a real scatter, matching the message traffic of manual Pregel code) and a
  *reply* superstep (the owner sends the value back — a gather);
* fixed-point termination is checked on host between supersteps, exactly like
  Pregel's aggregator round-trip.

The executed-superstep count is returned and cross-checked in tests against
the STM cost models of ``repro.core.stm``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ast
from repro.core.analysis import analyze_step
from repro.core.codegen import HALTED, StepExecutor, make_stop_fn
from repro.core.logic import PullSolver
from repro.graph import ops as gops


@dataclasses.dataclass
class BSPResult:
    fields: Dict[str, jax.Array]
    supersteps: int
    trips: List[int]


def _read_patterns(info) -> list:
    """Chain patterns a step's read phase must materialize: vertex-context
    chains plus multi-hop neighborhood chains. Shared by the staged stage
    builder and :func:`read_superstep_count` so the two can never diverge."""
    pats = set(info.chain_patterns)
    for _, npat in info.nbr_comms:
        if len(npat) > 1:
            pats.add(npat)
    return sorted(pats)


class _StagedStep:
    """One Palgol step compiled to a list of superstep callables."""

    def __init__(self, step: ast.Step, graph, schedule: str):
        self.step = step
        self.graph = graph
        self.schedule = schedule
        self.info = analyze_step(step)
        # chain patterns needed (vertex-context chains + neighborhood chains)
        self.patterns = _read_patterns(self.info)
        self._remote_schedule = None  # (field, op) order, discovered lazily

    # -- read supersteps -----------------------------------------------------
    def read_stage_fns(self):
        """List of jitted (fields, mailbox) -> mailbox functions; one per
        remote-reading superstep."""
        if not self.patterns and not self.info.nbr_comms:
            return []
        if self.schedule == "pull":
            return self._pull_read_stages()
        return self._naive_read_stages()

    def _nbr_send(self, mailbox_out, fields, mailbox_in):
        """Materialize per-edge neighborhood buffers (the 'send' superstep)."""
        for direction, npat in sorted(self.info.nbr_comms):
            nbr, _, _, _ = self.graph.edges(direction)
            val = self._lookup(fields, mailbox_in, npat)
            mailbox_out[_nkey(direction, npat)] = gops.gather(val, nbr)

    def _pull_read_stages(self):
        """One stage per gather round: chain DAG nodes grouped by depth, and
        the neighborhood send piggybacked on the round after its chain is
        ready (matching StepInfo.pull_read_rounds)."""
        solver = PullSolver()
        order = solver.schedule(self.patterns)
        depth = {p: solver.solve(p).rounds for p in order}
        total_rounds = self.info.pull_read_rounds()
        # neighborhood sends fire at round rounds(pattern)+1
        nbr_round = {
            (d, p): solver.rounds(p) + 1 for d, p in self.info.nbr_comms
        }
        stages = []
        for r in range(1, total_rounds + 1):
            todo = tuple(p for p in order if depth.get(p) == r and len(p) > 1)
            sends = tuple(k for k, rr in nbr_round.items() if rr == r)

            def stage(fields, mailbox, _todo=todo, _sends=sends, _solver=solver):
                out = dict(mailbox)
                for p in _todo:
                    plan = _solver.solve(p)
                    pre = self._lookup(fields, out, plan.prefix.pattern)
                    suf = self._lookup(fields, out, plan.suffix.pattern)
                    out[_key(p)] = gops.gather(suf, pre)
                for direction, npat in _sends:
                    nbr, _, _, _ = self.graph.edges(direction)
                    val = self._lookup(fields, out, npat)
                    out[_nkey(direction, npat)] = gops.gather(val, nbr)
                return out

            stages.append(jax.jit(stage))
        return stages

    def _naive_read_stages(self):
        """Request/reply per hop, sequentially per pattern (manual style),
        then one neighborhood-send superstep."""
        stages = []
        chain_pats = list(self.patterns)
        # chains hanging off e.id also resolve hop by hop in manual code
        for _, npat in sorted(self.info.nbr_comms):
            if len(npat) > 1 and npat not in chain_pats:
                chain_pats.append(npat)
        for p in chain_pats:
            for k in range(2, len(p) + 1):
                prefix = p[:k]

                def request(fields, mailbox, _prefix=prefix):
                    # requester u pushes its id to the owner vertex (real
                    # scatter: the message traffic manual Pregel code pays)
                    out = dict(mailbox)
                    owner = self._lookup(fields, out, _prefix[:-1])
                    ids = jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
                    reqbuf = jnp.full_like(ids, self.graph.n_vertices)
                    out[_key(_prefix) + ":req"] = reqbuf.at[owner].set(
                        ids, mode="drop"
                    )
                    return out

                def reply(fields, mailbox, _prefix=prefix):
                    # owner replies with its field value → requester buffer
                    out = dict(mailbox)
                    owner = self._lookup(fields, out, _prefix[:-1])
                    val = (
                        jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
                        if _prefix[-1] == "Id"
                        else fields[_prefix[-1]]
                    )
                    out[_key(_prefix)] = gops.gather(val, owner)
                    out.pop(_key(_prefix) + ":req", None)
                    return out

                stages.append(jax.jit(request))
                stages.append(jax.jit(reply))
        if self.info.nbr_comms:

            def send(fields, mailbox):
                out = dict(mailbox)
                self._nbr_send(out, fields, mailbox)
                return out

            stages.append(jax.jit(send))
        return stages

    def _lookup(self, fields, mailbox, pattern):
        if len(pattern) == 0:
            return jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
        if len(pattern) == 1:
            if pattern[0] == "Id":
                return jnp.arange(self.graph.n_vertices, dtype=jnp.int32)
            return fields[pattern[0]]
        return mailbox[_key(pattern)]

    # -- main + update supersteps ---------------------------------------------
    def main_fn(self):
        has_ru = self.info.has_remote_writes()

        def main(fields, mailbox):
            chain_values = {
                p: mailbox[_key(p)] for p in self.patterns if _key(p) in mailbox
            }
            nbr_values = {
                (d, p): mailbox[_nkey(d, p)]
                for d, p in self.info.nbr_comms
                if _nkey(d, p) in mailbox
            }
            ex = StepExecutor(self.step, self.graph)
            if has_ru:
                new, pending = ex(
                    fields, chain_values, split_remote=True, nbr_values=nbr_values
                )
                payload = [(m.idx, m.values, m.mask) for m in pending]
                return new, payload
            return ex(fields, chain_values, nbr_values=nbr_values), []

        return jax.jit(main)

    def update_fn(self):
        def update(fields, payload):
            ex = StepExecutor(self.step, self.graph)
            # rebuild message descriptors: (field, op) order is the static
            # program order of remote writes, discovered from the AST
            descs = _remote_write_descs(self.step)
            from repro.core.codegen import _RemoteMsg

            msgs = [
                _RemoteMsg(f, op, idx, val, mask)
                for (f, op), (idx, val, mask) in zip(descs, payload)
            ]
            return ex.apply_remote(fields, msgs)

        return jax.jit(update)


def _remote_write_descs(step: ast.Step) -> List[Tuple[str, str]]:
    descs = []
    for s in ast.walk_stmts(step.body):
        if isinstance(s, ast.RemoteWrite):
            descs.append((s.field, s.op))
    return descs


def read_superstep_count(step: ast.Step, schedule: str) -> int:
    """Number of remote-reading supersteps a step costs under ``schedule``.

    Mirrors ``len(_StagedStep.read_stage_fns())`` exactly (validated by the
    partition equivalence tests) so alternative placements — e.g. the
    partitioned executor, whose reads happen as collectives inside a fused
    dispatch — charge the same superstep totals as the staged dense path.
    """
    info = analyze_step(step)
    pats = _read_patterns(info)
    if not pats and not info.nbr_comms:
        return 0
    if schedule == "pull":
        return info.pull_read_rounds()
    # naive: request + reply per chain hop, then one neighborhood send
    n = sum(2 * (len(p) - 1) for p in pats)
    return n + (1 if info.nbr_comms else 0)


def _key(pattern) -> str:
    return "chain:" + "/".join(pattern)


def _nkey(direction, pattern) -> str:
    return f"nbr:{direction}:" + "/".join(pattern)


def walk_program(
    prog: ast.Prog,
    fields,
    exec_step,
    exec_stop,
    counter: List[int],
    trips: List[int],
    max_iters: int,
):
    """Host-side superstep walk shared by every placement.

    ``exec_step(step, fields)`` / ``exec_stop(stop, fields)`` execute one
    Step / StopStep (and account their own supersteps in ``counter``); this
    walker owns sequencing, the iteration Init superstep (paper Fig. 11),
    trip counting, and the host-side OR-aggregator fixed-point check — so
    iteration semantics cannot diverge between the replicated and
    partitioned executors.
    """

    def run(p, flds):
        if isinstance(p, ast.Step):
            return exec_step(p, flds)
        if isinstance(p, ast.StopStep):
            return exec_stop(p, flds)
        if isinstance(p, ast.Seq):
            for q in p.progs:
                flds = run(q, flds)
            return flds
        if isinstance(p, ast.Iter):
            # the iteration Init superstep: sets up the OR-aggregator so
            # the first termination check succeeds
            counter[0] += 1
            trips.append(0)
            slot = len(trips) - 1
            limit = p.fixed_trips if p.fixed_trips is not None else max_iters
            for _ in range(limit):
                before = {f: flds[f] for f in p.fix_fields}
                flds = run(p.body, flds)
                trips[slot] += 1
                if p.fix_fields:
                    # host-side aggregator round-trip (Pregel OR-aggregator)
                    changed = any(
                        bool(jnp.any(flds[f] != before[f]))
                        for f in p.fix_fields
                    )
                    if not changed:
                        break
            return flds
        raise TypeError(type(p))

    return run(prog, fields)


def run_bsp(
    prog: ast.Prog,
    graph,
    fields: Dict[str, jax.Array],
    schedule: str = "pull",
    max_iters: int = 100_000,
    placement: str = "replicated",
    mesh=None,
    n_shards: Optional[int] = None,
) -> BSPResult:
    """Execute a Palgol program superstep-by-superstep.

    ``fields`` must be the full canonical field dict (use
    ``CompiledProgram.init_fields``). Returns final fields, the number of
    actually executed supersteps, and per-iteration trip counts.

    ``placement`` selects the vertex-state layout:

    * ``"replicated"`` (default) — dense single-address-space arrays; under
      an active mesh GSPMD/shard_map keep vertex state replicated per chip;
    * ``"partitioned"`` — edge-balanced contiguous-range shards with halo
      exchange (``repro.graph.partition``): each superstep moves only
      boundary state. ``mesh`` (a 1-D ``("shard",)`` mesh) or ``n_shards``
      selects the layout; defaults to one shard per local device. Fields
      are partitioned on entry and returned dense, so callers are
      placement-agnostic.
    """
    if placement == "partitioned":
        from repro.graph.partition import run_bsp_partitioned

        return run_bsp_partitioned(
            prog, graph, fields, schedule=schedule, max_iters=max_iters,
            mesh=mesh, n_shards=n_shards,
        )
    if placement != "replicated":
        raise ValueError(f"unknown placement {placement!r}")
    counter = [0]
    trips: List[int] = []
    # cache compiled stage functions per Step/StopStep node: supersteps
    # re-execute across iterations without re-tracing (as a real Pregel
    # binary would)
    cache: Dict[int, object] = {}

    def exec_step(step: ast.Step, flds):
        if id(step) not in cache:
            staged = _StagedStep(step, graph, schedule)
            cache[id(step)] = (
                staged,
                staged.read_stage_fns(),
                staged.main_fn(),
                staged.update_fn() if staged.info.has_remote_writes() else None,
            )
        staged, read_fns, main_fn, update_fn = cache[id(step)]
        mailbox: Dict[str, jax.Array] = {}
        for stage in read_fns:
            mailbox = stage(flds, mailbox)
            counter[0] += 1
        new, payload = main_fn(flds, mailbox)
        counter[0] += 1
        if update_fn is not None:
            new = update_fn(new, payload)
            counter[0] += 1
        return new

    def exec_stop(stop: ast.StopStep, flds):
        if id(stop) not in cache:
            cache[id(stop)] = jax.jit(make_stop_fn(stop, graph))
        counter[0] += 1
        return cache[id(stop)](flds)

    fields = {k: jnp.asarray(v) for k, v in fields.items()}
    if HALTED not in fields:
        fields[HALTED] = jnp.zeros((graph.n_vertices,), jnp.bool_)
    out = walk_program(
        prog, fields, exec_step, exec_stop, counter, trips, max_iters
    )
    return BSPResult(fields=out, supersteps=counter[0], trips=trips)
