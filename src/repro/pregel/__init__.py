"""Pregel BSP runtime: superstep-by-superstep execution of compiled Palgol.

The dense executor (repro.core.codegen) fuses a whole Palgol program into one
XLA computation — the production path. This package provides the *staged*
executor that dispatches one device computation per Pregel superstep with a
host-side barrier between them (the shape of a real Pregel system), used for

* validating the STM superstep accounting against actually-executed steps,
* the Table-4-style execution-time comparison (fused Palgol output vs the
  naive/manual compilation with request-reply chains and no merging/fusion).
"""

from repro.pregel.runtime import run_bsp, BSPResult

__all__ = ["run_bsp", "BSPResult"]
