"""Batched serving driver: prefill + continuous decode on a model config.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
        --reduced --batch 4 --prompt-len 64 --decode-steps 64

The production path mirrors the decode_* dry-run cells: jit'd prefill
(last-position logits) + jit'd decode step over the ring-buffer KV cache,
both shardable against the production mesh (see launch/dryrun.py for the
lowering). On this CPU container use --reduced.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.transformer import model as tm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    spec = configs.get_spec(args.arch)
    if spec.family != "lm":
        raise SystemExit(f"{args.arch} is not an LM architecture")
    cfg = spec.reduced if args.reduced else spec.config
    params = tm.init(jax.random.PRNGKey(args.seed), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1),
        (args.batch, args.prompt_len), 0, cfg.vocab_size,
    )
    capacity = tm.cache_len(cfg, args.prompt_len + args.decode_steps)

    prefill = jax.jit(
        lambda p, t: tm.prefill(p, t, cfg, capacity=capacity,
                                full_logits=False)
    )
    decode = jax.jit(lambda p, c, t: tm.decode_step(p, c, t, cfg))

    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(prefill(params, prompts))
    dt = time.perf_counter() - t0
    print(f"prefill {args.batch}×{args.prompt_len}: {dt*1e3:.1f} ms "
          f"({args.batch * args.prompt_len / dt:,.0f} tok/s), "
          f"cache capacity {capacity}")

    def sample(logits, key):
        if args.temperature <= 0:
            return jnp.argmax(logits, -1)
        return jax.random.categorical(key, logits / args.temperature, -1)

    key = jax.random.PRNGKey(args.seed + 2)
    cur = sample(logits, key)[:, None].astype(jnp.int32)
    out = [cur]
    t0 = time.perf_counter()
    for i in range(args.decode_steps):
        logits, cache = decode(params, cache, cur)
        key, sub = jax.random.split(key)
        cur = sample(logits, sub)[:, None].astype(jnp.int32)
        out.append(cur)
    jax.block_until_ready(cur)
    dt = time.perf_counter() - t0
    print(f"decode {args.decode_steps} steps: {dt*1e3:.1f} ms "
          f"({args.batch * args.decode_steps / dt:,.0f} tok/s)")
    seq = jnp.concatenate(out, axis=1)
    print("first stream:", seq[0, :24].tolist())


if __name__ == "__main__":
    main()
