"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax

from repro.dist import compat  # noqa: F401  (AxisType/make_mesh shims)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod (TPU v5e pod slice); 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic restarts re-shape here)."""
    return jax.make_mesh(
        tuple(shape),
        tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )
