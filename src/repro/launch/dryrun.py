import os
# NOTE: while-loop LICM is disabled because XLA:CPU shadows every bf16 dot
# operand with an f32 convert; LICM hoists those converts out of the scan
# loops, materializing f32 copies of whole [L,B,S,D] remat stacks. TPU has
# native bf16 MXU input, so the hoisted copies don't exist there — disabling
# the pass makes the CPU memory analysis TPU-faithful.
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion"
)

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build abstract parameters (ShapeDtypeStructs — zero host
memory), jit the real step function (train step WITH optimizer update, or
prefill/decode/serve), lower against the production mesh, compile, and
record ``memory_analysis()`` (proves it fits), ``cost_analysis()`` (flops /
bytes for §Roofline) and the collective-bytes breakdown parsed from the
partitioned HLO.

    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k --mesh single

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json and are
summarized into EXPERIMENTS.md by benchmarks/roofline_report.py.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs
from repro.dist import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.gnn import models as gm
from repro.models.recsys import autoint
from repro.models.transformer import model as tm
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.roofline.analysis import (
    HW,
    collective_bytes_from_hlo,
    roofline_terms,
)

OUT_DIR = Path("experiments/dryrun")


# ---------------------------------------------------------------------------
# per-family step functions + input specs


def _lm_probe_cfg(cfg):
    """2-layer fully-unrolled variant: XLA cost analysis counts while-loop
    bodies once, so f(probe2) − f(scan) isolates one true layer's cost."""
    return dataclasses.replace(cfg, n_layers=2, scan_unroll=2)


# gradient-accumulation microbatches per (arch, shape): the global batch is
# unchanged (identical optimizer semantics); activation memory scales 1/M.
# Unrolled python loop, so cost_analysis counts every microbatch.
MICROBATCH = {
    ("qwen3-moe-235b-a22b", "train_4k"): 8,
    ("qwen3-32b", "train_4k"): 2,
    ("qwen2.5-32b", "train_4k"): 2,
    ("deepseek-moe-16b", "train_4k"): 2,
}


# "fsdp" (2D params) vs "zero1" (model-sharded params, 2D optimizer state).
# Hillclimb result (EXPERIMENTS §Perf): zero1 removes the per-layer weight
# all-gathers (428→30 GB/dev on qwen3-32b train) and still fits; dense-LM
# train cells default to it. MoE archs must stay fsdp — expert stacks are
# 29 GB/device without the data-axis shard.
PARAM_MODE = {
    ("qwen3-32b", "train_4k"): "zero1",
    ("qwen2.5-32b", "train_4k"): "zero1",
    ("h2o-danube-1.8b", "train_4k"): "zero1",
}


def lm_cell(spec, shape_id, shape, mesh, cfg=None):
    cfg = cfg or spec.config
    kind = shape["kind"]
    seq, batch = shape["seq_len"], shape["global_batch"]
    params = tm.abstract_params(cfg)
    mode = PARAM_MODE.get((spec.arch_id, shape_id), "fsdp")
    pshard = shd.param_shardings("lm", params, mesh, mode=mode)
    oc = AdamWConfig(
        state_dtype="bfloat16" if cfg.n_params() > 1e11 else None
    )
    if kind == "train":
        opt = jax.eval_shape(lambda p: adamw_init(p, oc), params)
        # optimizer state always 2D-sharded (ZeRO-1 keeps it sharded even
        # when the stored params are only model-sharded)
        opt_shard_leaf = shd.param_shardings("lm", params, mesh, mode="fsdp")
        oshard = {
            "m": opt_shard_leaf,
            "v": opt_shard_leaf,
            "step": shd.replicated(jnp.zeros(()), mesh),
        }
        batch_specs = tm.input_specs(cfg, "train", seq, batch)
        bshard = shd.batch_shardings("lm", batch_specs, mesh)
        micro = MICROBATCH.get((spec.arch_id, shape_id), 1)

        def step(p, o, b):
            if micro == 1:
                loss, g = jax.value_and_grad(
                    lambda q: tm.loss_fn(q, b, cfg)
                )(p)
            else:
                # gradient accumulation via lax.scan: one microbatch's
                # buffers alive at a time (an unrolled loop lets XLA:CPU
                # keep every microbatch's temporaries simultaneously —
                # refuted hypothesis H6 in EXPERIMENTS.md §Perf)
                mb = batch // micro
                stacked = {
                    k: v.reshape((micro, mb) + v.shape[1:])
                    for k, v in b.items()
                }

                def mb_body(carry, sub):
                    loss_acc, g_acc = carry
                    li, gi = jax.value_and_grad(
                        lambda q: tm.loss_fn(q, sub, cfg)
                    )(p)
                    g_acc = jax.tree_util.tree_map(
                        lambda a, c: a + c / micro, g_acc, gi
                    )
                    return (loss_acc + li / micro, g_acc), None

                g0 = jax.tree_util.tree_map(
                    lambda q: jnp.zeros(q.shape, jnp.bfloat16
                                        if q.dtype == jnp.bfloat16
                                        else jnp.float32),
                    p,
                )
                (loss, g), _ = jax.lax.scan(
                    mb_body, (jnp.zeros((), jnp.float32), g0), stacked
                )
            p, o = adamw_update(g, o, p, oc)
            return p, o, loss

        fn = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        args = (params, opt, batch_specs)
        tokens = batch * seq
        model_flops = 6.0 * cfg.n_active_params() * tokens
    elif kind == "prefill":
        batch_specs = tm.input_specs(cfg, "prefill", seq, batch)
        bshard = shd.batch_shardings("lm", batch_specs, mesh)
        cache_c = tm.cache_len(cfg, seq)
        cache_spec = shd.lm_cache_spec(mesh, cfg, batch, cache_c)
        from jax.sharding import NamedSharding, PartitionSpec as P

        out_shard = (
            NamedSharding(mesh, shd.lm_batch_spec(mesh, batch)),
            {
                "k": NamedSharding(mesh, cache_spec),
                "v": NamedSharding(mesh, cache_spec),
                "length": NamedSharding(mesh, P()),
            },
        )

        def step(p, b):
            # production prefill: last-position logits only (sampling needs
            # no more; full [B,S,V] logits would be ~20 GB/device at 32k)
            return tm.prefill(p, b["tokens"], cfg, full_logits=False)

        fn = jax.jit(step, in_shardings=(pshard, bshard),
                     out_shardings=out_shard)
        args = (params, batch_specs)
        model_flops = 2.0 * cfg.n_active_params() * batch * seq
    elif kind == "decode":
        specs = tm.input_specs(cfg, "decode", seq, batch)
        cache_c = tm.cache_len(cfg, seq)
        from jax.sharding import NamedSharding, PartitionSpec as P

        cache_spec = shd.lm_cache_spec(mesh, cfg, batch, cache_c)
        cshard = {
            "k": NamedSharding(mesh, cache_spec),
            "v": NamedSharding(mesh, cache_spec),
            "length": NamedSharding(mesh, P()),
        }
        tshard = NamedSharding(
            mesh, shd.lm_batch_spec(mesh, batch)
        )

        def step(p, cache, toks):
            return tm.decode_step(p, cache, toks, cfg)

        fn = jax.jit(
            step,
            in_shardings=(pshard, cshard, tshard),
            out_shardings=(tshard, cshard),
            donate_argnums=(1,),
        )
        args = (params, specs["cache"], specs["tokens"])
        # per-token weight read + KV attention flops
        kv_flops = (
            2.0 * batch * cfg.n_layers * cfg.n_heads * cache_c
            * cfg.head_dim * 2
        )
        model_flops = 2.0 * cfg.n_active_params() * batch + kv_flops
    else:
        raise ValueError(kind)
    return fn, args, model_flops


def _pad1024(n: int) -> int:
    """Graph arrays are padded so node/edge counts divide the mesh axes —
    otherwise batch-sharding constraints silently drop (masked rows are the
    standard padding mechanism of the substrate)."""
    return -(-n // 1024) * 1024


def gnn_cell(spec, shape_id, shape, mesh):
    cfg = configs.resolve_gnn_config(spec.config, shape_id, shape)
    kind = shape["kind"]
    if kind == "full_graph":
        shape = dict(
            shape,
            n_nodes=_pad1024(shape["n_nodes"]),
            n_edges=_pad1024(shape["n_edges"]),
        )
    oc = AdamWConfig()
    if kind == "minibatch":
        # generic sampled-subgraph: seeds + 2 sampled hops as a block graph
        b = shape["batch_nodes"]
        f0, f1 = shape["fanouts"]
        n_sub = b * (1 + f0 + f0 * f1)
        e_sub = b * (f0 + f0 * f1)
        batch_specs = gm.input_specs(
            cfg, "full_graph", n_nodes=n_sub, n_edges=e_sub,
            d_feat=shape["d_feat"],
        )
    elif kind == "batched_graphs":
        batch_specs = gm.input_specs(
            cfg, "batched_graphs", batch=shape["batch"],
            n_nodes=shape["n_nodes"], n_edges=shape["n_edges"],
            d_feat=shape["d_feat"],
        )
    else:
        batch_specs = gm.input_specs(
            cfg, "full_graph", n_nodes=shape["n_nodes"],
            n_edges=shape["n_edges"], d_feat=shape["d_feat"],
        )
    params = gm.abstract_params(cfg)
    pshard = shd.param_shardings("gnn", params, mesh)
    opt = jax.eval_shape(lambda p: adamw_init(p, oc), params)
    oshard = {"m": pshard, "v": pshard,
              "step": shd.replicated(jnp.zeros(()), mesh)}
    bshard = shd.batch_shardings("gnn", batch_specs, mesh)

    def step(p, o, b):
        loss, g = jax.value_and_grad(lambda q: gm.loss_fn(q, b, cfg))(p)
        p, o = adamw_update(g, o, p, oc)
        return p, o, loss

    fn = jax.jit(
        step,
        in_shardings=(pshard, oshard, bshard),
        out_shardings=(pshard, oshard, None),
        donate_argnums=(0, 1),
    )
    args = (params, opt, batch_specs)
    # analytic model flops: 3 matmul passes (fwd + 2 bwd) over layer matmuls
    n_nodes = batch_specs["x"].shape[0]
    n_edges = batch_specs["src"].shape[0]
    d = cfg.d_hidden
    d_in = cfg.d_in
    per_layer = 2 * n_nodes * (d_in if cfg.n_layers == 1 else d) * d
    if cfg.variant == "graphcast":
        per_layer += 2 * n_edges * (2 * d + cfg.d_edge) * cfg.d_edge
    model_flops = 3.0 * (
        2 * n_nodes * d_in * d + (cfg.n_layers - 1) * per_layer
    )
    return fn, args, model_flops


def recsys_cell(spec, shape_id, shape, mesh):
    cfg = spec.config
    kind = shape["kind"]
    batch = shape["batch"]
    params = autoint.abstract_params(cfg)
    pshard = shd.param_shardings("recsys", params, mesh)
    if kind == "train":
        oc = AdamWConfig()
        opt = jax.eval_shape(lambda p: adamw_init(p, oc), params)
        oshard = {"m": pshard, "v": pshard,
                  "step": shd.replicated(jnp.zeros(()), mesh)}
        batch_specs = autoint.input_specs(cfg, "train", batch)
        bshard = shd.batch_shardings("gnn", batch_specs, mesh)

        def step(p, o, b):
            loss, g = jax.value_and_grad(
                lambda q: autoint.loss_fn(q, b, cfg)
            )(p)
            p, o = adamw_update(g, o, p, oc)
            return p, o, loss

        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        args = (params, opt, batch_specs)
    elif kind == "serve":
        batch_specs = autoint.input_specs(cfg, "serve", batch)
        bshard = shd.batch_shardings("gnn", batch_specs, mesh)

        def step(p, b):
            return autoint.forward(p, b, cfg)

        fn = jax.jit(step, in_shardings=(pshard, bshard))
        args = (params, batch_specs)
    else:  # retrieval
        batch_specs = autoint.input_specs(
            cfg, "retrieval", batch, n_candidates=shape["n_candidates"]
        )
        bshard = shd.batch_shardings("gnn", batch_specs, mesh)

        def step(p, b):
            return autoint.retrieval_score(p, b, cfg)

        fn = jax.jit(step, in_shardings=(pshard, bshard))
        args = (params, batch_specs)
    # interaction + MLP flops (embedding lookups are bytes, not flops)
    f, da = cfg.n_fields, cfg.d_attn
    attn_flops = cfg.n_attn_layers * (
        2 * f * (cfg.embed_dim * da * 3) + 2 * f * f * da * 2
    )
    mlp_flops = 2 * sum(
        a * b
        for a, b in zip((f * da,) + cfg.mlp_dims, cfg.mlp_dims + (1,))
    )
    mult = 3.0 if kind == "train" else 1.0
    model_flops = mult * batch * (attn_flops + mlp_flops)
    if kind == "retrieval":
        model_flops += 2.0 * shape["n_candidates"] * da
    return fn, args, model_flops


# ---------------------------------------------------------------------------
# driver


def _cost_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on jax ≥ 0.4.38 but a
    one-element list of dicts on older jaxlibs — normalize to the dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost if cost is not None else {}


def _f32_shadow_estimate(hlo: str) -> int:
    """Bytes of f32 buffers that are dtype-shadows of bf16 buffers (same
    dims in both dtypes). Each distinct shadowed shape counted once."""
    import re as _re

    shapes = {"f32": set(), "bf16": set()}
    for m in _re.finditer(r"(f32|bf16)\[([0-9,]+)\]", hlo):
        shapes[m.group(1)].add(m.group(2))
    total = 0
    for dims in shapes["f32"] & shapes["bf16"]:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 > 1 << 27:  # only count ≥128 MB twins
            total += n * 4
    return total


def dryrun_cell(arch_id: str, shape_id: str, mesh_kind: str,
                hw: HW = HW()) -> dict:
    spec = configs.get_spec(arch_id)
    shape = spec.shapes[shape_id]
    skip = spec.skips.get(shape_id)
    rec = {
        "arch": arch_id,
        "shape": shape_id,
        "mesh": mesh_kind,
        "shape_params": {k: v for k, v in shape.items()},
    }
    if skip:
        rec.update(status="skipped", reason=skip)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    maker = {"lm": lm_cell, "gnn": gnn_cell, "recsys": recsys_cell}[spec.family]
    t0 = time.time()
    try:
        shd.activate(mesh)
        with mesh:
            fn, args, model_flops = maker(spec, shape_id, shape, mesh)
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
            mem = compiled.memory_analysis()
            cost = _cost_dict(compiled)
            hlo = compiled.as_text()
            probe = None
            if spec.family == "lm" and spec.config.n_layers > 2:
                # scan-body flops correction probe (see _lm_probe_cfg)
                fn2, args2, _ = lm_cell(
                    spec, shape_id, shape, mesh, cfg=_lm_probe_cfg(spec.config)
                )
                compiled2 = fn2.lower(*args2).compile()
                probe = (
                    _cost_dict(compiled2),
                    compiled2.as_text(),
                )
    except Exception as e:  # record failures — they are bugs to fix
        rec.update(
            status="failed",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )
        return rec
    finally:
        shd.deactivate()
    coll = collective_bytes_from_hlo(hlo, n_dev)
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    shadow = _f32_shadow_estimate(hlo)
    correction = None
    if probe is not None:
        cost2, hlo2 = probe
        L = spec.config.n_layers
        micro = MICROBATCH.get((arch_id, shape_id), 1) if spec.family == "lm" else 1
        lf = max(float(cost2.get("flops", 0.0)) - flops_dev, 0.0)
        lb = max(float(cost2.get("bytes accessed", 0.0)) - bytes_dev, 0.0)
        coll2 = collective_bytes_from_hlo(hlo2, n_dev)
        lc = {
            k: max(coll2[k] - coll[k], 0.0) for k in coll
        }
        correction = {
            "layer_flops_per_device": lf,
            "layer_bytes_per_device": lb,
            "layer_collective_bytes": lc["total"],
            "microbatch_multiplier": micro,
        }
        # the microbatch scan is also counted once by cost_analysis; the
        # optimizer (outside the scan) is counted fully but is negligible
        flops_dev = micro * (flops_dev + (L - 1) * lf)
        bytes_dev = micro * (bytes_dev + (L - 1) * lb)
        coll = {k: micro * (coll[k] + (L - 1) * lc[k]) for k in coll}
    terms = roofline_terms(
        flops_dev, bytes_dev, coll["total"], n_dev, hw, model_flops
    )
    peak_dev_bytes = (
        mem.argument_size_in_bytes
        + mem.output_size_in_bytes
        + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes
    )
    # XLA:CPU wraps every bf16 dot operand in an f32 convert (no native
    # bf16 matmul); the resulting f32 twins of bf16 buffers don't exist on
    # TPU (MXU consumes bf16). `corrected` subtracts one f32 twin per
    # distinct shadowed shape — a conservative TPU-faithful estimate.
    corrected = max(peak_dev_bytes - shadow, 0)
    rec.update(
        status="ok",
        n_devices=n_dev,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_bytes": peak_dev_bytes,
            "fits_16GB": bool(peak_dev_bytes < hw.hbm_bytes),
            "cpu_f32_shadow_bytes": shadow,
            "peak_tpu_corrected_bytes": corrected,
            "fits_16GB_corrected": bool(corrected < hw.hbm_bytes),
        },
        cost={
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "raw_flops_per_device": float(cost.get("flops", 0.0)),
            "scan_correction": correction,
        },
        collectives=coll,
        roofline=terms,
    )
    return rec


def _palgol_step_plans(algos=("sssp", "wcc", "sv", "chain4"), costs=None) -> dict:
    """Per-step superstep plans (repro.core.plan) for the representative
    programs, under every schedule — what the partitioned executor will
    dispatch, printed so a pod-scale dry-run shows the op-by-op shape of
    each superstep before any device exists. ``costs`` (a ByteCostModel
    instrumented from the pod-scale partition) annotates every plan with
    its modeled wire bytes and adds the byte-aware ``auto`` pick under a
    sparse-request-set regime."""
    import dataclasses as _dc

    import jax.numpy as jnp

    from repro.core import algorithms as alg, compile_program
    from repro.core import plan as plan_mod
    from repro.core.plan import SCHEDULES, program_plan_records
    from repro.graph import generators as G

    small = G.erdos_renyi(64, 4.0, directed=False, weighted=True, seed=0)
    out = {}
    for name in algos:
        init_fields = None
        if name == "chain4":
            init_fields = {"D": jnp.zeros((64,), jnp.int32)}
        cp = compile_program(alg.ALL[name], small, initial_fields=init_fields)
        cell = {
            sched: program_plan_records(cp.step_plans(sched), costs=costs)
            for sched in SCHEDULES
        }
        if costs is not None:
            cell["auto_bytes"] = program_plan_records(
                _dc.replace(cp, byte_costs=costs).step_plans("auto"),
                costs=costs,
            )
        # the §4.3-fused program schedule the executors dispatch by default:
        # merged supersteps + the per-iteration saving, vs the unfused base
        unfused = plan_mod.lower_program(cp.prog, schedule="pull")
        fused = plan_mod.fuse(unfused)
        ub, up, _ = unfused.cost()
        fb, fp, _ = fused.cost()
        cell["fused_program"] = {
            "items": fused.describe(),
            "base": fb,
            "per_iter": {str(k): v for k, v in fp.items()},
            "unfused_base": ub,
            "unfused_per_iter": {str(k): v for k, v in up.items()},
        }
        out[name] = cell
    return out


def palgol_partition_cell(n_shards: int = 256, scale: int = 18) -> dict:
    """Dry-run the partitioned Palgol layout at pod shard counts.

    The partitioner is host-side, so validating the pod-scale layout needs
    no devices at all: partition an R-MAT graph (the paper's power-law
    regime) into one shard per production chip and record balance, halo
    size, projected per-superstep bytes vs the replicated layout, and the
    per-step superstep plans each schedule would dispatch.
    Writes ``experiments/dryrun/palgol_partition.json``.
    """
    from repro.graph import generators as G
    from repro.graph.partition import byte_cost_model, comm_bytes_report

    g = G.rmat(scale, avg_degree=16.0, directed=True, seed=0)
    rec = comm_bytes_report(g, n_shards)
    stats = rec["partition"]
    rec = dict(rec)
    rec["status"] = "ok"
    rec["balance"] = (
        max(stats["pull_edges_per_shard"])
        / max(1.0, stats["n_edges"] / n_shards)
    )
    # byte model instrumented from this pod-scale layout, in the sparse
    # regime (request set = the measured halo — the boundary-active case
    # where the byte-aware auto abandons pull at deep chains)
    costs = byte_cost_model(
        g, n_shards,
        request_set=max(1, stats["halo_total"]),
        combined_request_set=max(1, stats["halo_total"] // 4),
    )
    rec["byte_cost_model"] = {
        "n_vertices": costs.n_vertices,
        "halo_bytes": costs.halo_bytes,
        "request_set": costs.request_set,
        "combined_request_set": costs.combined_request_set,
    }
    rec["step_plans"] = _palgol_step_plans(costs=costs)
    for name, cell in rec["step_plans"].items():
        for sched, steps in cell.items():
            if sched == "fused_program":
                print(
                    f"plan {name} fused program: base={steps['base']} "
                    f"per_iter={steps['per_iter']} (unfused "
                    f"base={steps['unfused_base']} "
                    f"per_iter={steps['unfused_per_iter']})",
                    flush=True,
                )
                for line in steps["items"]:
                    print(f"  {line}", flush=True)
                continue
            for i, s in enumerate(steps):
                print(
                    f"plan {name} step{i} [{sched}->{s['resolved']}] "
                    f"({s['supersteps']} ss, ~{s.get('bytes', 0)/1e3:.1f}KB): "
                    f"{s['ops']}",
                    flush=True,
                )
    path = OUT_DIR / "palgol_partition.json"
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(rec, indent=2))
    red = rec["reduction_vs_replicated"]
    print(
        f"palgol-partition: shards={n_shards} n={stats['n_vertices']} "
        f"e={stats['n_edges']} balance={rec['balance']:.3f} "
        f"halo_total={stats['halo_total']} "
        f"reduction={'inf' if red is None else f'{red:.2f}'}x",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--palgol-partition", action="store_true",
                    help="host-side pod-scale partition layout dry-run only")
    ap.add_argument("--shards", type=int, default=256)
    ap.add_argument("--graph-scale", type=int, default=18)
    args = ap.parse_args()

    if args.palgol_partition:
        palgol_partition_cell(args.shards, args.graph_scale)
        return

    archs = configs.all_arch_ids() if (args.all or not args.arch) else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out_root = Path(args.out)
    n_ok = n_fail = n_skip = 0
    for mesh_kind in meshes:
        for arch in archs:
            spec = configs.get_spec(arch)
            shapes = [args.shape] if args.shape else list(spec.shapes)
            for shape_id in shapes:
                path = out_root / mesh_kind / f"{arch}__{shape_id}.json"
                if args.skip_existing and path.exists():
                    prev = json.loads(path.read_text())
                    if prev.get("status") == "ok":
                        print(f"[cached] {mesh_kind} {arch} {shape_id}")
                        n_ok += 1
                        continue
                print(f"[dryrun] {mesh_kind} {arch} {shape_id} ...", flush=True)
                rec = dryrun_cell(arch, shape_id, mesh_kind)
                path.parent.mkdir(parents=True, exist_ok=True)
                path.write_text(json.dumps(rec, indent=2))
                st = rec["status"]
                n_ok += st == "ok"
                n_fail += st == "failed"
                n_skip += st == "skipped"
                if st == "ok":
                    m = rec["memory"]
                    r = rec["roofline"]
                    print(
                        f"  ok: compile={rec['compile_s']}s "
                        f"peak/dev={m['peak_per_device_bytes']/1e9:.2f}GB "
                        f"fits={m['fits_16GB']} "
                        f"bottleneck={r['bottleneck']} "
                        f"roofline_frac={r.get('roofline_fraction', 0):.3f}",
                        flush=True,
                    )
                    print("  memory_analysis:", rec["memory"], flush=True)
                    print(
                        "  cost_analysis:",
                        {
                            k: f"{v:.3e}"
                            for k, v in rec["cost"].items()
                            if isinstance(v, float)
                        },
                        flush=True,
                    )
                elif st == "failed":
                    print(f"  FAILED: {rec['error']}", flush=True)
                else:
                    print(f"  skipped: {rec['reason']}", flush=True)
    print(f"done: ok={n_ok} failed={n_fail} skipped={n_skip}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
