"""End-to-end training driver: config → data → pjit step → supervised loop.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --reduced \
        --steps 200 --ckpt-dir /tmp/ckpt --batch 8 --seq 128

Production posture wired in: sharded pjit step (full configs against the
production mesh), AdamW + cosine schedule + clipping, async checkpoints,
bounded-retry restart, straggler monitoring, failure injection for drills,
optional int8-compressed DP gradients. On this CPU container use
``--reduced`` (the same code path, small dims, 1-device mesh).
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.data.pipeline import gnn_full_batch, recsys_batches, token_batches
from repro.dist import sharding as shd
from repro.ft import FailureInjector, StragglerMonitor, TrainSupervisor
from repro.launch.mesh import make_mesh
from repro.models.gnn import models as gm
from repro.models.recsys import autoint
from repro.models.transformer import model as tm
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


def make_train_mesh():
    """2-D ``(data, model)`` mesh over the local devices (model=1: the live
    loop is DP/FSDP-first; the dry-run explores wider model axes). One
    device degrades to a 1×1 mesh, so every sharding spec still resolves."""
    return make_mesh((jax.device_count(), 1), ("data", "model"))


def build(arch: str, reduced: bool, batch: int, seq: int, seed: int):
    spec = configs.get_spec(arch)
    cfg = spec.reduced if reduced else spec.config
    key = jax.random.PRNGKey(seed)
    if spec.family == "lm":
        params = tm.init(key, cfg)

        def loss_fn(p, b):
            return tm.loss_fn(p, b, cfg)

        data = token_batches(batch, seq, cfg.vocab_size, seed=seed)
        batches = [next(data) for _ in range(16)]

        def batch_for_step(i):
            return batches[i % len(batches)]

    elif spec.family == "gnn":
        cfg_r = cfg
        params = gm.init(key, cfg_r)

        def loss_fn(p, b):
            return gm.loss_fn(p, b, cfg_r)

        fb = gnn_full_batch(
            max(batch * 16, 64), 6.0, cfg_r.d_in, cfg_r.n_out, seed=seed,
            task=cfg_r.task, n_out=cfg_r.n_out,
        )

        def batch_for_step(i):
            return fb

    else:
        params = autoint.init(key, cfg)

        def loss_fn(p, b):
            return autoint.loss_fn(p, b, cfg)

        data = recsys_batches(batch, cfg.n_fields, cfg.vocab_per_field,
                              seed=seed)
        batches = [next(data) for _ in range(16)]

        def batch_for_step(i):
            return batches[i % len(batches)]

    return spec, cfg, params, loss_fn, batch_for_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="h2o-danube-1.8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated step indices to fail at (drill)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    spec, cfg, params, loss_fn, batch_for_step = build(
        args.arch, args.reduced, args.batch, args.seq, args.seed
    )
    oc = AdamWConfig(lr=args.lr)
    opt = adamw_init(params, oc)
    state = {"params": params, "opt": opt}

    # explicit placement instead of letting jit infer it: params by the
    # family's path-keyed rules, optimizer moments sharded like the params,
    # batches over the mesh's data group — and the old state donated, so
    # params/opt update in place (no 2× state footprint per step)
    mesh = make_train_mesh()
    shd.activate(mesh)
    pshard = shd.param_shardings(spec.family, params, mesh)
    state_shard = {
        "params": pshard,
        "opt": {
            "m": pshard,
            "v": pshard,
            "step": shd.replicated(jnp.zeros(()), mesh),
        },
    }
    bshard = shd.batch_shardings(spec.family, batch_for_step(0), mesh)
    # the donating step consumes its input buffers, so the supervisor's
    # restore-and-replay template must be durable: hand it a host-side
    # snapshot (dispatch device_puts it per in_shardings; steps after the
    # first flow device-to-device)
    state = jax.device_get(state)

    @functools.partial(
        jax.jit,
        in_shardings=(state_shard, bshard),
        out_shardings=(state_shard, None),
        donate_argnums=(0,),
    )
    def step_fn(state, batch):
        p, o = state["params"], state["opt"]
        loss, g = jax.value_and_grad(loss_fn)(p, batch)
        lr_scale = cosine_schedule(o["step"], warmup=args.warmup,
                                   total=args.steps)
        p, o = adamw_update(g, o, p, oc, lr_scale=lr_scale)
        return {"params": p, "opt": o}, {"loss": loss}

    injector = None
    if args.inject_failures:
        injector = FailureInjector(
            [int(x) for x in args.inject_failures.split(",")]
        )
    log = {"last": time.perf_counter()}

    def wrapped_step(state, batch):
        new_state, metrics = step_fn(state, batch)
        s = int(new_state["opt"]["step"])
        if s % args.log_every == 0:
            now = time.perf_counter()
            print(
                f"step {s:5d} loss {float(metrics['loss']):.4f} "
                f"({now - log['last']:.2f}s/{args.log_every} steps)",
                flush=True,
            )
            log["last"] = now
        return new_state, metrics

    sup = TrainSupervisor(
        wrapped_step,
        batch_for_step,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        injector=injector,
        straggler=StragglerMonitor(),
        on_straggler=lambda ev: print(f"[straggler] {ev}", flush=True),
    )
    state, step, metrics = sup.run(state, args.steps)
    print(
        f"done at step {step}: loss={float(metrics['loss']):.4f} "
        f"retries={sup.retries} restarts={sup.restarts} "
        f"stragglers={len(sup.straggler.events)}"
    )


if __name__ == "__main__":
    main()
