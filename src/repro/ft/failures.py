"""Fault-tolerance harness: retry, stragglers, preemption, failure injection.

At thousands of nodes, *something* is always failing. The supervisor wraps
the train step with:

* **checkpoint/restart** — resume from the newest complete checkpoint on
  (re)start; periodic async saves; save-on-preemption (SIGTERM);
* **bounded retry** — a failed step restores the last checkpoint and
  replays (covers transient ICI/host faults); repeated failures escalate;
* **straggler detection** — per-step wall-time EMA; steps slower than
  ``straggler_factor ×`` EMA fire a callback (at deployment: trigger
  hot-spare swap / re-slice; here: recorded + surfaced in metrics);
* **failure injection** — deterministic fault schedules for tests/drills.

The data-loader contract is a step-indexed iterator factory, so replays are
deterministic (same batch for a replayed step).
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint


class InjectedFailure(RuntimeError):
    pass


@dataclass
class FailureInjector:
    """Raise at the given (0-based) step indices — once each."""

    fail_at: List[int] = field(default_factory=list)
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected fault at step {step}")


@dataclass
class StragglerMonitor:
    """EMA-based slow-step detector."""

    factor: float = 3.0
    alpha: float = 0.1
    warmup: int = 3
    ema: Optional[float] = None
    events: List[Dict] = field(default_factory=list)
    _seen: int = 0

    def observe(self, step: int, dt: float) -> bool:
        self._seen += 1
        if self.ema is None:
            self.ema = dt
            return False
        is_straggler = (
            self._seen > self.warmup and dt > self.factor * self.ema
        )
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
        else:
            # stragglers don't poison the EMA
            self.ema = (1 - self.alpha) * self.ema + self.alpha * dt
        return is_straggler


class TrainSupervisor:
    """Run a train loop with checkpoint/restart + retry + stragglers.

    ``step_fn(state, batch) -> (state, metrics)`` must be pure;
    ``state`` is any pytree (params/opt/step counter).
    """

    def __init__(
        self,
        step_fn: Callable,
        batch_for_step: Callable[[int], object],
        ckpt_dir: str,
        ckpt_every: int = 50,
        max_retries: int = 3,
        injector: Optional[FailureInjector] = None,
        straggler: Optional[StragglerMonitor] = None,
        on_straggler: Optional[Callable[[Dict], None]] = None,
    ):
        self.step_fn = step_fn
        self.batch_for_step = batch_for_step
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.injector = injector
        self.straggler = straggler or StragglerMonitor()
        self.on_straggler = on_straggler
        self.retries = 0
        self.restarts = 0
        self._preempted = False

    def _install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def run(self, init_state, n_steps: int, mesh=None, sharding_fn=None):
        """Train to ``n_steps``; resumes from the newest checkpoint if any."""
        self._install_preemption_handler()
        state = init_state
        start = 0
        if latest_step(self.ckpt_dir) is not None:
            state, start, _ = restore_checkpoint(
                self.ckpt_dir, init_state, mesh=mesh, sharding_fn=sharding_fn
            )
            self.restarts += 1
        step = start
        metrics = None
        while step < n_steps:
            if self._preempted:
                self.ckpt.wait()
                self.ckpt.save(step, state, {"preempted": True})
                self.ckpt.wait()
                raise SystemExit(143)
            batch = self.batch_for_step(step)
            t0 = time.perf_counter()
            try:
                if self.injector is not None:
                    self.injector.maybe_fail(step)
                state, metrics = self.step_fn(state, batch)
            except SystemExit:
                raise
            except Exception:
                self.retries += 1
                if self.retries > self.max_retries:
                    raise
                # restore-and-replay from last durable state; an in-flight
                # async save is durable too — join it before scanning, or a
                # failure right after ckpt.save() replays from much older
                # state than necessary
                self.ckpt.wait()
                ls = latest_step(self.ckpt_dir)
                if ls is not None:
                    state, step, _ = restore_checkpoint(
                        self.ckpt_dir, init_state, mesh=mesh,
                        sharding_fn=sharding_fn,
                    )
                else:
                    state, step = init_state, 0
                continue
            dt = time.perf_counter() - t0
            if self.straggler.observe(step, dt) and self.on_straggler:
                self.on_straggler(self.straggler.events[-1])
            step += 1
            if step % self.ckpt_every == 0 or step == n_steps:
                self.ckpt.save(step, state)
        self.ckpt.wait()
        return state, step, metrics
