from repro.ft.failures import (
    FailureInjector,
    StragglerMonitor,
    TrainSupervisor,
)

__all__ = ["FailureInjector", "StragglerMonitor", "TrainSupervisor"]
