"""AutoInt [arXiv:1810.11921]: 39 sparse fields, 3 self-attn layers."""

from repro.configs.common import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import AutoIntConfig


def spec() -> ArchSpec:
    cfg = AutoIntConfig(
        name="autoint",
        n_fields=39,
        embed_dim=16,
        n_attn_layers=3,
        n_heads=2,
        d_attn=32,
        vocab_per_field=1_000_000,
    )
    reduced = AutoIntConfig(
        name="autoint-reduced",
        n_fields=8,
        embed_dim=8,
        n_attn_layers=2,
        n_heads=2,
        d_attn=16,
        vocab_per_field=1_000,
        mlp_dims=(32,),
    )
    return ArchSpec(
        arch_id="autoint", family="recsys", config=cfg, reduced=reduced,
        shapes=RECSYS_SHAPES,
    )
