"""PNA [arXiv:2004.05718]: multi-aggregator (mean/max/min/std) × scalers."""

from repro.configs.common import ArchSpec, GNN_SHAPES
from repro.models.gnn import GNNConfig


def spec() -> ArchSpec:
    cfg = GNNConfig(
        name="pna",
        variant="pna",
        n_layers=4,
        d_hidden=75,
        d_in=-1,  # set per shape (d_feat)
        n_out=-1,  # set per shape (classes)
        pna_aggregators=("mean", "max", "min", "std"),
        pna_scalers=("identity", "amplification", "attenuation"),
        compute_dtype="bfloat16",  # 62M-edge messages; head/loss stay fp32
    )
    reduced = GNNConfig(
        name="pna-reduced", variant="pna", n_layers=2, d_hidden=8, d_in=6,
        n_out=3,
    )
    return ArchSpec(
        arch_id="pna", family="gnn", config=cfg, reduced=reduced,
        shapes=GNN_SHAPES,
    )
