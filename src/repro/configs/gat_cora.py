"""GAT-Cora [arXiv:1710.10903]: 2 layers, 8 heads, d_hidden 8, attn agg."""

from repro.configs.common import ArchSpec, GNN_SHAPES
from repro.models.gnn import GNNConfig


def spec() -> ArchSpec:
    cfg = GNNConfig(
        name="gat-cora",
        variant="gat",
        n_layers=2,
        d_hidden=8,
        n_heads=8,
        d_in=-1,
        n_out=-1,
    )
    reduced = GNNConfig(
        name="gat-reduced", variant="gat", n_layers=2, d_hidden=4, n_heads=2,
        d_in=6, n_out=3,
    )
    return ArchSpec(
        arch_id="gat-cora", family="gnn", config=cfg, reduced=reduced,
        shapes=GNN_SHAPES,
    )
