"""h2o-danube-1.8b [arXiv:2401.16818]: llama+mistral mix with SWA, GQA kv=8."""

from repro.configs.common import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def spec() -> ArchSpec:
    cfg = TransformerConfig(
        name="h2o-danube-1.8b",
        n_layers=24,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        d_head=80,
        swa_window=4096,  # sliding-window attention (mistral-style)
        rope_theta=10_000.0,
    )
    reduced = TransformerConfig(
        name="h2o-danube-reduced",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        d_head=8,
        swa_window=32,
        rope_theta=10_000.0,
        param_dtype="float32",
        compute_dtype="float32",
        attn_chunk_q=16,
        attn_chunk_kv=16,
    )
    return ArchSpec(
        arch_id="h2o-danube-1.8b",
        family="lm",
        config=cfg,
        reduced=reduced,
        shapes=LM_SHAPES,
        notes="SWA ⇒ sub-quadratic: long_500k decode runs with a "
        "window-bounded (4096) KV ring buffer.",
    )
