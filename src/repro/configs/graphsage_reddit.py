"""GraphSAGE-Reddit [arXiv:1706.02216]: 2 layers, mean agg, fanout 25-10."""

from repro.configs.common import ArchSpec, GNN_SHAPES
from repro.models.gnn import GNNConfig


def spec() -> ArchSpec:
    cfg = GNNConfig(
        name="graphsage-reddit",
        variant="sage",
        n_layers=2,
        d_hidden=128,
        d_in=-1,
        n_out=-1,
        aggregator="mean",
        fanouts=(25, 10),
    )
    reduced = GNNConfig(
        name="sage-reduced", variant="sage", n_layers=2, d_hidden=8, d_in=6,
        n_out=3, fanouts=(5, 3),
    )
    return ArchSpec(
        arch_id="graphsage-reddit", family="gnn", config=cfg, reduced=reduced,
        shapes=GNN_SHAPES,
        notes="minibatch_lg uses the native sampler fanouts (25,10) from the "
        "arch (shape's 15-10 applies to the generic sampled-subgraph path).",
    )
