"""Architecture registry: ``get_spec(arch_id)`` / ``all_arch_ids()``.

Each assigned architecture has one module with the exact published config,
a reduced smoke config, and its shape table. ``resolve_gnn_config`` binds the
shape-dependent dims (d_feat, n_classes) that GNN configs leave open.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.common import (
    ArchSpec,
    GNN_SHAPE_CLASSES,
    GNN_SHAPES,
    LM_SHAPES,
    RECSYS_SHAPES,
)

_MODULES = {
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "qwen3-32b": "repro.configs.qwen3_32b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "pna": "repro.configs.pna",
    "graphsage-reddit": "repro.configs.graphsage_reddit",
    "graphcast": "repro.configs.graphcast",
    "gat-cora": "repro.configs.gat_cora",
    "autoint": "repro.configs.autoint",
}


def all_arch_ids() -> List[str]:
    return list(_MODULES)


def get_spec(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_MODULES)}"
        )
    return importlib.import_module(_MODULES[arch_id]).spec()


def resolve_gnn_config(cfg, shape_id: str, shape: Dict):
    """Bind shape-dependent dims (d_in from d_feat, n_out from the dataset's
    class count) into a GNN config."""
    d_in = shape.get("d_feat", cfg.d_in)
    updates = {"d_in": d_in}
    if cfg.n_out < 0:
        updates["n_out"] = GNN_SHAPE_CLASSES.get(shape_id, 16)
    if shape.get("kind") == "batched_graphs" and cfg.task == "node_class":
        updates["task"] = "graph_class"
    return dataclasses.replace(cfg, **updates)
