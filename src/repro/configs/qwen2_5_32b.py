"""qwen2.5-32b [hf:Qwen/Qwen2.5 family]: GQA kv=8, QKV bias."""

from repro.configs.common import ArchSpec, FULL_ATTN_LONG_SKIP, LM_SHAPES
from repro.models.transformer import TransformerConfig


def spec() -> ArchSpec:
    cfg = TransformerConfig(
        name="qwen2.5-32b",
        n_layers=64,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        d_ff=27648,
        vocab_size=152064,
        d_head=128,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        attn_chunk_q=512,
        attn_chunk_kv=512,
    )
    reduced = TransformerConfig(
        name="qwen2.5-32b-reduced",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        d_head=8,
        qkv_bias=True,
        param_dtype="float32",
        compute_dtype="float32",
        attn_chunk_q=16,
        attn_chunk_kv=16,
    )
    return ArchSpec(
        arch_id="qwen2.5-32b",
        family="lm",
        config=cfg,
        reduced=reduced,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTN_LONG_SKIP},
    )
