"""deepseek-moe-16b [arXiv:2401.06066]: 2 shared + 64 routed top-6, MHA."""

from repro.configs.common import ArchSpec, FULL_ATTN_LONG_SKIP, LM_SHAPES
from repro.models.transformer import MoEConfig, TransformerConfig


def spec() -> ArchSpec:
    cfg = TransformerConfig(
        name="deepseek-moe-16b",
        n_layers=28,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,  # MHA (kv == heads)
        d_ff=1408,
        vocab_size=102400,
        d_head=128,
        rope_theta=10_000.0,
        moe=MoEConfig(
            n_experts=64, top_k=6, d_ff_expert=1408, n_shared_experts=2
        ),
    )
    reduced = TransformerConfig(
        name="deepseek-moe-reduced",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab_size=256,
        d_head=16,
        param_dtype="float32",
        compute_dtype="float32",
        attn_chunk_q=16,
        attn_chunk_kv=16,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32, n_shared_experts=1),
    )
    return ArchSpec(
        arch_id="deepseek-moe-16b",
        family="lm",
        config=cfg,
        reduced=reduced,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTN_LONG_SKIP},
        notes="Paper's layer-0 dense FFN simplified to MoE everywhere "
        "(noted in DESIGN.md deviations).",
    )
