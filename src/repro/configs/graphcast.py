"""GraphCast [arXiv:2212.12794]: encoder-processor-decoder mesh GNN."""

from repro.configs.common import ArchSpec, GNN_SHAPES
from repro.models.gnn import GNNConfig


def spec() -> ArchSpec:
    cfg = GNNConfig(
        name="graphcast",
        variant="graphcast",
        n_layers=16,
        d_hidden=512,
        d_in=-1,  # per-shape d_feat (precomputed frame embeddings)
        n_out=227,  # n_vars
        d_edge=512,
        task="regression",
        compute_dtype="bfloat16",  # 62M-edge x 512 activations: bf16 halves
        # the per-layer edge-feature footprint (loss/head stay fp32)
    )
    reduced = GNNConfig(
        name="graphcast-reduced", variant="graphcast", n_layers=2,
        d_hidden=16, d_in=6, n_out=5, d_edge=16, task="regression",
    )
    return ArchSpec(
        arch_id="graphcast", family="gnn", config=cfg, reduced=reduced,
        shapes=GNN_SHAPES,
        notes="mesh_refinement=6 icosahedral mesh replaced by the shape's "
        "graph (the processor is topology-agnostic); regression over 227 vars.",
    )
