"""Arch/shape registry dataclasses + the assigned shape tables."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

# ---------------------------------------------------------------------------
# shape tables (verbatim from the assignment)

LM_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq_len=4_096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32_768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524_288, global_batch=1),
}

GNN_SHAPES: Dict[str, Dict[str, Any]] = {
    "full_graph_sm": dict(
        kind="full_graph", n_nodes=2_708, n_edges=10_556, d_feat=1_433
    ),
    "minibatch_lg": dict(
        kind="minibatch",
        n_nodes=232_965,
        n_edges=114_615_892,
        batch_nodes=1_024,
        fanouts=(15, 10),
        d_feat=602,  # Reddit features
    ),
    "ogb_products": dict(
        kind="full_graph", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100
    ),
    "molecule": dict(
        kind="batched_graphs", n_nodes=30, n_edges=64, batch=128, d_feat=32
    ),
}

RECSYS_SHAPES: Dict[str, Dict[str, Any]] = {
    "train_batch": dict(kind="train", batch=65_536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262_144),
    "retrieval_cand": dict(kind="retrieval", batch=1, n_candidates=1_000_000),
}

# classes per GNN shape (dataset-realistic)
GNN_SHAPE_CLASSES = {
    "full_graph_sm": 7,  # cora
    "minibatch_lg": 41,  # reddit
    "ogb_products": 47,
    "molecule": 10,
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str  # "lm" | "gnn" | "recsys"
    config: Any
    reduced: Any  # small config for CPU smoke tests
    shapes: Dict[str, Dict[str, Any]]
    # cells skipped per harness rules: shape_id → reason
    skips: Dict[str, str] = dataclasses.field(default_factory=dict)
    notes: str = ""

    def cells(self):
        for shape_id in self.shapes:
            yield shape_id, self.shapes[shape_id], self.skips.get(shape_id)


FULL_ATTN_LONG_SKIP = (
    "long_500k skipped: pure full attention (no sub-quadratic mechanism); "
    "see DESIGN.md §5"
)
