"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family]: 128 experts top-8."""

from repro.configs.common import ArchSpec, FULL_ATTN_LONG_SKIP, LM_SHAPES
from repro.models.transformer import MoEConfig, TransformerConfig


def spec() -> ArchSpec:
    cfg = TransformerConfig(
        name="qwen3-moe-235b-a22b",
        n_layers=94,
        d_model=4096,
        n_heads=64,
        n_kv_heads=4,
        d_ff=1536,  # unused (all layers MoE); kept for reporting parity
        vocab_size=151936,
        d_head=128,
        qk_norm=True,
        rope_theta=1_000_000.0,
        moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=1536),
    )
    reduced = TransformerConfig(
        name="qwen3-moe-reduced",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        d_head=16,
        qk_norm=True,
        param_dtype="float32",
        compute_dtype="float32",
        attn_chunk_q=16,
        attn_chunk_kv=16,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32),
    )
    return ArchSpec(
        arch_id="qwen3-moe-235b-a22b",
        family="lm",
        config=cfg,
        reduced=reduced,
        shapes=LM_SHAPES,
        skips={"long_500k": FULL_ATTN_LONG_SKIP},
        notes="Optimizer state dtype bf16 at the 235B scale (see DESIGN.md).",
    )
