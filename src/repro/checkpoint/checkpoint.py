"""Sharded, elastic, async checkpointing.

Layout per step:  <dir>/step_<n>/
    manifest.json   — step, mesh shape/axes, per-leaf partition specs, dtypes
    arrays.npz      — logical (unsharded) array contents, flat-key indexed

Design points for the 1000-node posture:

* **Atomicity** — writes land in ``step_<n>.tmp`` and are renamed only when
  complete, so a preemption mid-write never corrupts the latest checkpoint
  (restore scans for the newest *complete* step).
* **Elasticity** — arrays are stored in logical layout plus their
  PartitionSpec; restore re-lays them onto *any* mesh (different pod count /
  axis sizes), recomputing shardings against the new mesh. A 2-pod job can
  restart as 1-pod and vice versa.
* **Async** — ``AsyncCheckpointer`` snapshots device arrays to host and
  writes on a background thread, overlapping I/O with the next train steps
  (compute/IO overlap); ``wait()`` joins before the next save or exit.
* On a real multi-host deployment each host writes only its addressable
  shards; the npz body here is the single-host degenerate case of the same
  manifest format.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        flat[key] = leaf
    return flat


def _spec_str(leaf) -> str:
    sh = getattr(leaf, "sharding", None)
    if sh is None or not hasattr(sh, "spec"):
        return ""
    return json.dumps([list(p) if isinstance(p, tuple) else p
                       for p in tuple(sh.spec)])


def save_checkpoint(
    directory: str | os.PathLike,
    step: int,
    tree,
    extra_meta: Optional[Dict] = None,
) -> Path:
    """Synchronous atomic checkpoint write. Returns the final path."""
    directory = Path(directory)
    final = directory / f"step_{step:08d}"
    tmp = directory / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    arrays = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    manifest = {
        "step": step,
        "keys": {
            k: {
                "shape": list(arrays[k].shape),
                "dtype": str(arrays[k].dtype),
                "spec": _spec_str(flat[k]),
            }
            for k in arrays
        },
        "extra": extra_meta or {},
    }
    np.savez(tmp / "arrays.npz", **arrays)
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str | os.PathLike) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    best = None
    for p in directory.iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "manifest.json").exists():
            best = max(best or -1, int(m.group(1)))
    return best


def restore_checkpoint(
    directory: str | os.PathLike,
    target_tree,
    step: Optional[int] = None,
    mesh=None,
    sharding_fn=None,
) -> Tuple[Any, int, Dict]:
    """Restore into the structure of ``target_tree``.

    ``mesh`` + the manifest's recorded specs (or an explicit
    ``sharding_fn(key, array) -> Sharding``) re-shard each array for the
    *current* mesh — this is the elastic-resize path: the stored layout is
    logical, so any device count works.
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = directory / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    data = np.load(d / "arrays.npz")

    flat_target, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    out_leaves = []
    for path, leaf in flat_target:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        if key not in data:
            raise KeyError(f"checkpoint missing key {key!r}")
        arr = data[key]
        if mesh is not None:
            if sharding_fn is not None:
                sh = sharding_fn(key, arr)
            else:
                spec_json = manifest["keys"][key]["spec"]
                from jax.sharding import NamedSharding, PartitionSpec

                if spec_json:
                    parts = [
                        tuple(p) if isinstance(p, list) else p
                        for p in json.loads(spec_json)
                    ]
                    # drop axes the new mesh doesn't have / can't divide
                    clean = []
                    for dim, p in enumerate(parts):
                        axes = (
                            tuple(a for a in (p if isinstance(p, tuple) else (p,))
                                  if a is not None)
                            if p is not None else ()
                        )
                        ok = all(a in mesh.shape for a in axes)
                        size = (
                            int(np.prod([mesh.shape[a] for a in axes]))
                            if axes
                            else 1
                        )
                        ok = ok and (
                            dim < arr.ndim and size and arr.shape[dim] % size == 0
                        )
                        clean.append(p if (ok and axes) else None)
                    sh = NamedSharding(mesh, PartitionSpec(*clean))
                else:
                    sh = NamedSharding(mesh, PartitionSpec())
            arr = jax.device_put(arr, sh)
        else:
            arr = jax.numpy.asarray(arr)
        out_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
    return tree, step, manifest.get("extra", {})


class AsyncCheckpointer:
    """Background-thread checkpoint writer (overlaps I/O with training)."""

    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree, extra_meta=None):
        self.wait()
        # snapshot to host synchronously (cheap vs device step time), write
        # in the background
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree
        )

        def _write():
            try:
                save_checkpoint(self.directory, step, host_tree, extra_meta)
                self._gc()
            except BaseException as e:  # surfaced at next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for p in self.directory.iterdir()
            if (m := re.fullmatch(r"step_(\d+)", p.name))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}", ignore_errors=True)
