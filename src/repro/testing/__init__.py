"""Test-support utilities (hypothesis fallback shim, seed helpers)."""
