"""Minimal, deterministic stand-in for ``hypothesis``.

The property tests in this repo use a small slice of hypothesis:
``@given`` / ``@settings`` and the ``integers`` / ``lists`` /
``sampled_from`` / ``booleans`` / ``floats`` / ``composite`` strategies.
When the real package is unavailable (hermetic CI images), ``conftest.py``
installs this module as ``hypothesis`` in ``sys.modules`` so the same test
code runs unmodified as *seeded random testing*:

* every ``@given`` test draws ``max_examples`` example tuples from a
  ``numpy`` Generator seeded by the test's qualified name — deterministic
  across runs and machines, independent of execution order;
* no shrinking, no example database, no health checks — on failure the
  raised exception carries the offending drawn values in its notes.

This is strictly weaker than hypothesis (no coverage-guided generation),
but it preserves the property-test *semantics* the suite encodes. If real
hypothesis is installed, the shim is never imported.
"""

from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

__version__ = "0.0-repro-stub"

_DEFAULT_MAX_EXAMPLES = 20


class Strategy:
    """A value generator: ``example(rng) -> value``."""

    def __init__(self, sample, label="strategy"):
        self._sample = sample
        self._label = label

    def example(self, rng):
        return self._sample(rng)

    def __repr__(self):
        return f"<{self._label}>"


class _Draw:
    """The ``draw`` callable handed to ``@composite`` functions."""

    def __init__(self, rng):
        self._rng = rng

    def __call__(self, strategy):
        return strategy.example(self._rng)


def _integers(min_value, max_value):
    return Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value}, {max_value})",
    )


def _booleans():
    return Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")


def _floats(min_value=0.0, max_value=1.0, **kw):
    del kw  # width / allow_nan etc. — not needed by this suite
    span = max_value - min_value
    return Strategy(
        lambda rng: float(min_value + span * rng.random()),
        f"floats({min_value}, {max_value})",
    )


def _sampled_from(elements):
    elements = list(elements)
    if not elements:
        raise ValueError("sampled_from requires a non-empty collection")
    return Strategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))],
        f"sampled_from({elements!r:.40})",
    )


def _lists(elements, min_size=0, max_size=None):
    if max_size is None:
        max_size = min_size + 10

    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.example(rng) for _ in range(n)]

    return Strategy(sample, f"lists(..., {min_size}, {max_size})")


def _composite(fn):
    """``@composite`` — ``fn(draw, *args)`` becomes a strategy factory."""

    @functools.wraps(fn)
    def factory(*args, **kwargs):
        return Strategy(
            lambda rng: fn(_Draw(rng), *args, **kwargs),
            f"composite:{fn.__name__}",
        )

    return factory


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.booleans = _booleans
strategies.floats = _floats
strategies.sampled_from = _sampled_from
strategies.lists = _lists
strategies.composite = _composite
strategies.SearchStrategy = Strategy


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **kw):
    """Decorator: records ``max_examples`` on the ``@given`` wrapper."""
    del deadline, kw  # accepted for signature compat, ignored

    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn

    return deco


class UnsatisfiedAssumption(Exception):
    """Raised by :func:`assume` to discard the current example."""


def given(*arg_strategies, **kw_strategies):
    """Run the test over deterministically-seeded random examples.

    Positional strategies bind to the test's *rightmost* parameters
    (matching real hypothesis, so a leading pytest fixture keeps working
    identically in both environments); keyword strategies bind by name.
    The wrapper's signature hides the bound parameters so pytest does not
    mistake them for fixtures.
    """

    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        has_self = bool(params) and params[0].name == "self"
        body = params[1:] if has_self else params
        pos_names = [
            p.name for p in body[len(body) - len(arg_strategies):]
        ]
        bound = set(pos_names) | set(kw_strategies)
        passthrough = ([params[0]] if has_self else []) + [
            p for p in body if p.name not in bound
        ]

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            done = discarded = 0
            while done < n:
                kw = {
                    name: s.example(rng)
                    for name, s in zip(pos_names, arg_strategies)
                }
                kw.update(
                    (k, s.example(rng)) for k, s in kw_strategies.items()
                )
                try:
                    fn(*args, **kw, **kwargs)
                except UnsatisfiedAssumption:
                    discarded += 1
                    if discarded > 20 * n:
                        raise RuntimeError(
                            f"{fn.__qualname__}: assume() discarded "
                            f"{discarded} examples for {done} accepted — "
                            "strategy filters too much"
                        )
                    continue
                except Exception as e:
                    e.args = e.args + (
                        f"[hypothesis-stub example {done}: kwargs={kw!r}]",
                    )
                    raise
                done += 1

        wrapper.__signature__ = sig.replace(parameters=passthrough)
        del wrapper.__wrapped__  # keep pytest off fn's original signature
        return wrapper

    return deco


def assume(condition):
    """Discard the current example when ``condition`` is falsy."""
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class HealthCheck:  # noqa: D401 - attribute bag for compat
    all = ()
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
