"""int8 gradient compression with error feedback (DP all-reduce shrinker).

At multi-pod scale the gradient all-reduce crosses DCN; int8 quantization
cuts that traffic 4× (vs f32) / 2× (vs bf16). Error feedback accumulates
the quantization residual locally and re-injects it next step, which keeps
SGD/Adam convergence (Seide et al.; Karimireddy et al. — EF-SGD).

Two entry points:
* ``compress``/``decompress`` — per-tensor symmetric int8 with max-abs
  scale (pure functions; composable with any optimizer);
* ``make_compressed_dp_grad_fn`` — explicit-collective data-parallel
  gradient via ``shard_map``: per-shard grads → EF + quantize → int32
  ``psum`` (exact integer summation) → dequantize mean. This is the
  explicit alternative to GSPMD's implicit all-reduce when you want the
  wire format under your control.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """g → (int8 q, f32 scale) with symmetric max-abs scaling."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_with_feedback(g, residual):
    """Error-feedback compression: returns (q, scale, new_residual)."""
    corrected = g.astype(jnp.float32) + residual
    q, scale = compress(corrected)
    new_residual = corrected - decompress(q, scale)
    return q, scale, new_residual


def make_compressed_dp_grad_fn(loss_fn, mesh, data_axis: str = "data"):
    """Data-parallel gradient with int8-over-the-wire all-reduce.

    Returns ``grad_fn(params, batch, residuals) -> (loss, grads, residuals)``
    where params are replicated, batch is sharded on ``data_axis``, and
    ``residuals`` is a params-shaped f32 pytree (init zeros).
    """
    from jax.experimental.shard_map import shard_map

    def local(params, batch, residuals):
        loss, g = jax.value_and_grad(loss_fn)(params, batch)
        n = jax.lax.psum(1, axis_name=data_axis)

        def reduce_leaf(gl, res):
            corrected = gl.astype(jnp.float32) + res
            # all shards must quantize against the SAME scale before the
            # integer sum — agree via a scalar pmax (negligible traffic)
            local_scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-30) / 127.0
            scale = jax.lax.pmax(local_scale, axis_name=data_axis)
            q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(
                jnp.int8
            )
            new_res = corrected - q.astype(jnp.float32) * scale
            total = jax.lax.psum(q.astype(jnp.int32), axis_name=data_axis)
            mean = total.astype(jnp.float32) * scale / n
            return mean.astype(gl.dtype), new_res

        flat_g, treedef = jax.tree_util.tree_flatten(g)
        flat_r = treedef.flatten_up_to(residuals)
        out = [reduce_leaf(a, b) for a, b in zip(flat_g, flat_r)]
        grads = treedef.unflatten([o[0] for o in out])
        new_res = treedef.unflatten([o[1] for o in out])
        loss = jax.lax.pmean(loss, axis_name=data_axis)
        return loss, grads, new_res

    batch_spec = P(data_axis)
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(), batch_spec, P()),
        out_specs=(P(), P(), P()),
        check_rep=False,
    )
