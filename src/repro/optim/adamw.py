"""AdamW with global-norm clipping and configurable state dtype.

State dtype matters at scale: fp32 m/v/master costs 12 bytes/param — on the
235B MoE that alone is 11 GB/chip on a 256-chip pod. ``state_dtype=bfloat16``
halves m/v (standard for very large MoE training); the update math always
runs in fp32.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: Optional[str] = None  # None → same dtype as fp32


def adamw_init(params, cfg: AdamWConfig):
    sdt = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else jnp.float32

    def zeros(p):
        return jnp.zeros(p.shape, sdt)

    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_update(grads, state, params, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_state). Math in fp32; params keep dtype."""
    step = state["step"] + 1
    if cfg.clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd_math(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    # NOTE: a lax.map-over-layers variant was tried to bound the f32 update
    # transients on giant stacked leaves; it *increased* peak memory by
    # breaking XLA's input/output buffer aliasing of the donated param and
    # optimizer-state stacks (EXPERIMENTS.md §Perf, refuted hypothesis H7).
    upd = upd_math

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
