"""LR schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, warmup: int = 100, total: int = 10_000,
                    min_ratio: float = 0.1):
    """Linear warmup → cosine decay to ``min_ratio``; returns a scale in
    (0, 1] multiplying the base LR."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return warm * (min_ratio + (1 - min_ratio) * cos)
