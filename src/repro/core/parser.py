"""Parser for the Palgol surface syntax (paper Fig. 2).

Palgol is indentation-based; the tokenizer synthesizes INDENT/DEDENT tokens
(the paper's '(' / ')' layout tokens) from leading whitespace, and a
recursive-descent parser builds the AST.

Grammar (as implemented — faithful to Fig. 2 + §3.4):

    prog   := item+
    item   := step | iter | stopstep
    step   := "for" var "in" "V" NEWLINE INDENT stmt+ DEDENT "end"
    stop   := "stop" var "in" "V" "if" exp
    iter   := "do" NEWLINE INDENT item+ DEDENT "until" "fix" "[" fields "]"
    stmt   := "if" exp block ("else" block)?
            | "for" "(" var "<-" exp ")" block
            | "let" var "=" exp
            | "local" field "[" var "]" op_local exp
            | "remote" field "[" exp "]" op_remote exp
    exp    := ternary with the usual precedence chain; primaries include
              literals, vars, field access F[e], e.id / e.w, comprehensions
              ``func [ exp | var <- exp, filters ]``, and parens.
"""

from __future__ import annotations

import re
from typing import List, Optional, Tuple

from repro.core import ast

_TOKEN_RE = re.compile(
    r"""
    (?P<float>\d+\.\d+(e[+-]?\d+)?|\d+e[+-]?\d+)
  | (?P<int>\d+)
  | (?P<name>[A-Za-z_][A-Za-z_0-9]*)
  | (?P<op><\?=|>\?=|\|\|=|&&=|\+=|-=|\*=|/=|:=|<-|==|!=|<=|>=|\|\||&&|[-+*/%<>=!?:,.|\[\]()])
  | (?P<ws>[ \t]+)
  | (?P<comment>\#[^\n]*|//[^\n]*)
    """,
    re.VERBOSE,
)

_KEYWORDS = {
    "for", "in", "V", "end", "do", "until", "fix", "if", "else", "let",
    "local", "remote", "stop", "true", "false", "inf",
}
_REDUCE_FUNCS = ast.REDUCE_FUNCS
_EDGE_LISTS = {"Nbr": "nbr", "In": "in", "Out": "out"}


class Token:
    __slots__ = ("kind", "value", "line")

    def __init__(self, kind, value, line):
        self.kind, self.value, self.line = kind, value, line

    def __repr__(self):
        return f"{self.kind}:{self.value!r}@{self.line}"


class PalgolSyntaxError(SyntaxError):
    pass


def tokenize(source: str) -> List[Token]:
    tokens: List[Token] = []
    indents = [0]
    for lineno, raw in enumerate(source.splitlines(), start=1):
        line = raw.rstrip()
        stripped = line.lstrip(" \t")
        if not stripped or stripped.startswith("#") or stripped.startswith("//"):
            continue
        indent = len(line) - len(stripped)
        if indent > indents[-1]:
            indents.append(indent)
            tokens.append(Token("INDENT", indent, lineno))
        while indent < indents[-1]:
            indents.pop()
            tokens.append(Token("DEDENT", indent, lineno))
        if indent != indents[-1]:
            raise PalgolSyntaxError(f"line {lineno}: inconsistent dedent")
        pos = 0
        while pos < len(stripped):
            m = _TOKEN_RE.match(stripped, pos)
            if not m:
                raise PalgolSyntaxError(
                    f"line {lineno}: cannot tokenize {stripped[pos:pos+10]!r}"
                )
            pos = m.end()
            kind = m.lastgroup
            if kind in ("ws", "comment"):
                continue
            val = m.group()
            if kind == "name":
                if val in _KEYWORDS:
                    tokens.append(Token(val, val, lineno))
                else:
                    tokens.append(Token("NAME", val, lineno))
            elif kind == "int":
                tokens.append(Token("INT", int(val), lineno))
            elif kind == "float":
                tokens.append(Token("FLOAT", float(val), lineno))
            else:
                tokens.append(Token("OP", val, lineno))
        tokens.append(Token("NEWLINE", None, lineno))
    while len(indents) > 1:
        indents.pop()
        tokens.append(Token("DEDENT", 0, -1))
    tokens.append(Token("EOF", None, -1))
    return tokens


class Parser:
    def __init__(self, tokens: List[Token]):
        self.toks = tokens
        self.i = 0

    # -- token helpers -----------------------------------------------------
    def peek(self, k=0) -> Token:
        return self.toks[min(self.i + k, len(self.toks) - 1)]

    def next(self) -> Token:
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect(self, kind, value=None) -> Token:
        t = self.next()
        if t.kind != kind or (value is not None and t.value != value):
            raise PalgolSyntaxError(
                f"line {t.line}: expected {value or kind}, got {t.kind}:{t.value!r}"
            )
        return t

    def accept(self, kind, value=None) -> Optional[Token]:
        t = self.peek()
        if t.kind == kind and (value is None or t.value == value):
            return self.next()
        return None

    def skip_newlines(self):
        while self.peek().kind == "NEWLINE":
            self.next()

    # -- program -----------------------------------------------------------
    def parse_program(self) -> ast.Prog:
        items = self.parse_items(until=("EOF",))
        self.expect("EOF")
        if len(items) == 1:
            return items[0]
        return ast.Seq(tuple(items))

    def parse_items(self, until: Tuple[str, ...]) -> List[ast.Prog]:
        items: List[ast.Prog] = []
        self.skip_newlines()
        while self.peek().kind not in until:
            items.append(self.parse_item())
            self.skip_newlines()
        return items

    def parse_item(self) -> ast.Prog:
        t = self.peek()
        if t.kind == "for":
            return self.parse_step()
        if t.kind == "do":
            return self.parse_iter()
        if t.kind == "stop":
            return self.parse_stop()
        raise PalgolSyntaxError(
            f"line {t.line}: expected step/do/stop, got {t.value!r}"
        )

    def parse_step(self) -> ast.Step:
        self.expect("for")
        var = self.expect("NAME").value
        self.expect("in")
        self.expect("V")
        self.expect("NEWLINE")
        self.expect("INDENT")
        body = self.parse_block_stmts()
        self.expect("DEDENT")
        self.expect("end")
        self.accept("NEWLINE")
        return ast.Step(var, tuple(body))

    def parse_stop(self) -> ast.StopStep:
        self.expect("stop")
        var = self.expect("NAME").value
        self.expect("in")
        self.expect("V")
        self.expect("if")
        cond = self.parse_expr()
        self.accept("NEWLINE")
        return ast.StopStep(var, cond)

    def parse_iter(self) -> ast.Iter:
        self.expect("do")
        self.expect("NEWLINE")
        self.expect("INDENT")
        items = self.parse_items(until=("DEDENT",))
        self.expect("DEDENT")
        self.expect("until")
        body_items = items
        body = body_items[0] if len(body_items) == 1 else ast.Seq(tuple(body_items))
        if self.peek().kind == "fix":
            self.next()
            self.expect("OP", "[")
            fields = [self.expect("NAME").value]
            while self.accept("OP", ","):
                fields.append(self.expect("NAME").value)
            self.expect("OP", "]")
            self.accept("NEWLINE")
            return ast.Iter(body, tuple(fields))
        t = self.expect("NAME")
        if t.value != "iter":
            raise PalgolSyntaxError(
                f"line {t.line}: expected 'fix' or 'iter' after until"
            )
        self.expect("OP", "[")
        k = self.expect("INT").value
        self.expect("OP", "]")
        self.accept("NEWLINE")
        return ast.Iter(body, (), fixed_trips=int(k))

    # -- statements ---------------------------------------------------------
    def parse_block_stmts(self) -> List[ast.Stmt]:
        stmts: List[ast.Stmt] = []
        self.skip_newlines()
        while self.peek().kind not in ("DEDENT", "EOF"):
            stmts.append(self.parse_stmt())
            self.skip_newlines()
        return stmts

    def parse_indented_block(self) -> Tuple[ast.Stmt, ...]:
        self.expect("NEWLINE")
        self.expect("INDENT")
        stmts = self.parse_block_stmts()
        self.expect("DEDENT")
        return tuple(stmts)

    def parse_stmt(self) -> ast.Stmt:
        t = self.peek()
        if t.kind == "if":
            self.next()
            cond = self.parse_expr()
            then = self.parse_indented_block()
            other: Tuple[ast.Stmt, ...] = ()
            if self.peek().kind == "else":
                self.next()
                other = self.parse_indented_block()
            return ast.If(cond, then, other)
        if t.kind == "for":
            self.next()
            self.expect("OP", "(")
            var = self.expect("NAME").value
            self.expect("OP", "<-")
            rng = self.parse_expr()
            self.expect("OP", ")")
            if not isinstance(rng, ast.EdgeList):
                raise PalgolSyntaxError(
                    f"line {t.line}: for-loop range must be Nbr/In/Out[...]"
                )
            body = self.parse_indented_block()
            return ast.ForEdges(var, rng, body)
        if t.kind == "let":
            self.next()
            var = self.expect("NAME").value
            self.expect("OP", "=")
            value = self.parse_expr()
            self.accept("NEWLINE")
            return ast.Let(var, value)
        if t.kind == "local":
            self.next()
            field = self.expect("NAME").value
            self.expect("OP", "[")
            idx_var = self.expect("NAME").value  # validated in analysis
            self.expect("OP", "]")
            op = self.expect("OP").value
            if op not in ast.LOCAL_OPS:
                raise PalgolSyntaxError(f"line {t.line}: bad local op {op!r}")
            value = self.parse_expr()
            self.accept("NEWLINE")
            return ast.LocalWrite(field, op, value, idx_var)
        if t.kind == "remote":
            self.next()
            field = self.expect("NAME").value
            self.expect("OP", "[")
            target = self.parse_expr()
            self.expect("OP", "]")
            op = self.expect("OP").value
            if op not in ast.REMOTE_OPS:
                raise PalgolSyntaxError(
                    f"line {t.line}: remote writes must be accumulative, got {op!r}"
                )
            value = self.parse_expr()
            self.accept("NEWLINE")
            return ast.RemoteWrite(field, target, op, value)
        raise PalgolSyntaxError(f"line {t.line}: unexpected {t.value!r}")

    # -- expressions ----------------------------------------------------------
    def parse_expr(self) -> ast.Expr:
        return self.parse_ternary()

    def parse_ternary(self) -> ast.Expr:
        cond = self.parse_or()
        if self.accept("OP", "?"):
            then = self.parse_ternary()
            self.expect("OP", ":")
            other = self.parse_ternary()
            return ast.Cond(cond, then, other)
        return cond

    def parse_or(self) -> ast.Expr:
        left = self.parse_and()
        while self.accept("OP", "||"):
            left = ast.BinOp("||", left, self.parse_and())
        return left

    def parse_and(self) -> ast.Expr:
        left = self.parse_cmp()
        while self.accept("OP", "&&"):
            left = ast.BinOp("&&", left, self.parse_cmp())
        return left

    def parse_cmp(self) -> ast.Expr:
        left = self.parse_add()
        t = self.peek()
        if t.kind == "OP" and t.value in ("==", "!=", "<", "<=", ">", ">="):
            self.next()
            return ast.BinOp(t.value, left, self.parse_add())
        return left

    def parse_add(self) -> ast.Expr:
        left = self.parse_mul()
        while True:
            t = self.peek()
            if t.kind == "OP" and t.value in ("+", "-"):
                self.next()
                left = ast.BinOp(t.value, left, self.parse_mul())
            else:
                return left

    def parse_mul(self) -> ast.Expr:
        left = self.parse_unary()
        while True:
            t = self.peek()
            if t.kind == "OP" and t.value in ("*", "/", "%"):
                self.next()
                left = ast.BinOp(t.value, left, self.parse_unary())
            else:
                return left

    def parse_unary(self) -> ast.Expr:
        t = self.peek()
        if t.kind == "OP" and t.value in ("!", "-"):
            self.next()
            return ast.UnOp(t.value, self.parse_unary())
        return self.parse_postfix()

    def parse_postfix(self) -> ast.Expr:
        e = self.parse_primary()
        while self.peek().kind == "OP" and self.peek().value == ".":
            self.next()
            prop = self.expect("NAME").value
            if prop not in ("id", "w"):
                raise PalgolSyntaxError(f"unknown edge property .{prop}")
            if not isinstance(e, ast.Var):
                raise PalgolSyntaxError(".id/.w only valid on edge variables")
            e = ast.EdgeProp(e.name, prop)
        return e

    def parse_primary(self) -> ast.Expr:
        t = self.next()
        if t.kind == "INT" or t.kind == "FLOAT":
            return ast.Const(t.value)
        if t.kind == "true":
            return ast.Const(True)
        if t.kind == "false":
            return ast.Const(False)
        if t.kind == "inf":
            return ast.Const("inf")
        if t.kind == "OP" and t.value == "(":
            e = self.parse_expr()
            self.expect("OP", ")")
            return e
        if t.kind == "NAME":
            name = t.value
            # reduce comprehension: func [ body | var <- range, filters ]
            if (
                name in _REDUCE_FUNCS
                and self.peek().kind == "OP"
                and self.peek().value == "["
            ):
                self.next()  # [
                body = self.parse_expr()
                self.expect("OP", "|")
                var = self.expect("NAME").value
                self.expect("OP", "<-")
                rng = self.parse_expr()
                if not isinstance(rng, ast.EdgeList):
                    raise PalgolSyntaxError(
                        f"line {t.line}: comprehension range must be Nbr/In/Out[...]"
                    )
                filters = []
                while self.accept("OP", ","):
                    filters.append(self.parse_expr())
                self.expect("OP", "]")
                return ast.Reduce(name, body, var, rng, tuple(filters))
            # edge lists / field access: Capitalized [ exp ]
            if self.peek().kind == "OP" and self.peek().value == "[":
                if not name[0].isupper():
                    raise PalgolSyntaxError(
                        f"line {t.line}: lowercase {name!r} cannot be indexed; "
                        "fields start with a capital letter"
                    )
                self.next()  # [
                idx = self.parse_expr()
                self.expect("OP", "]")
                if name in _EDGE_LISTS:
                    return ast.EdgeList(_EDGE_LISTS[name], idx)
                return ast.FieldAccess(name, idx)
            if name[0].isupper():
                raise PalgolSyntaxError(
                    f"line {t.line}: field {name!r} must be indexed (Field[expr])"
                )
            return ast.Var(name)
        raise PalgolSyntaxError(f"line {t.line}: unexpected token {t.value!r}")


def parse(source: str) -> ast.Prog:
    """Parse Palgol source text into an AST."""
    return Parser(tokenize(source)).parse_program()
