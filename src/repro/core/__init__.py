"""Palgol: the paper's contribution — DSL, logic solver, compiler, runtimes.

Public API:
    parse(source)                        -> ast.Program
    compile_program(prog, fields, graph) -> CompiledProgram (dense + bsp modes)
    interpret(prog, fields, graph)       -> reference oracle result
    repro.core.algorithms                -> stdlib of Palgol programs
"""

from repro.core.parser import parse
from repro.core.compiler import compile_program
from repro.core.interpreter import interpret
from repro.core.plan import (
    ByteCostModel,
    ProgramPlan,
    StepPlan,
    fuse,
    lower_program,
    lower_step,
    plan_bytes,
)

__all__ = [
    "parse",
    "compile_program",
    "interpret",
    "ByteCostModel",
    "ProgramPlan",
    "StepPlan",
    "fuse",
    "lower_program",
    "lower_step",
    "plan_bytes",
]
