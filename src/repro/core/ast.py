"""Palgol abstract syntax (paper Fig. 2, plus the §3.4 inactivation step).

The AST is deliberately small and immutable. Conventions:
* ``var``   — lowercase identifiers (vertex/edge/let variables)
* ``field`` — capitalized identifiers (global per-vertex arrays)
* Edge lists ``Nbr``/``In``/``Out`` are fields of a predefined edge type and
  only appear as the range of comprehensions / for-loops.
* Local writes: ``:=``, ``+=``, ``*=``, ``<?=`` (min), ``>?=`` (max),
  ``||=``, ``&&=``. Remote writes: accumulative only (everything but ``:=``).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# expressions


class Expr:
    __slots__ = ()


@dataclasses.dataclass(frozen=True)
class Const(Expr):
    value: object  # int | float | bool | "inf"

    def __repr__(self):
        return f"Const({self.value!r})"


@dataclasses.dataclass(frozen=True)
class Var(Expr):
    name: str


@dataclasses.dataclass(frozen=True)
class FieldAccess(Expr):
    """``Field [ exp ]`` — a global field read (possibly remote)."""

    field: str
    index: Expr


@dataclasses.dataclass(frozen=True)
class EdgeProp(Expr):
    """``e.id`` / ``e.w`` on an edge-loop variable."""

    edge_var: str
    prop: str  # "id" | "w"


@dataclasses.dataclass(frozen=True)
class Cond(Expr):
    cond: Expr
    then: Expr
    other: Expr


@dataclasses.dataclass(frozen=True)
class BinOp(Expr):
    op: str  # + - * / % == != < <= > >= && ||
    left: Expr
    right: Expr


@dataclasses.dataclass(frozen=True)
class UnOp(Expr):
    op: str  # ! -
    operand: Expr


@dataclasses.dataclass(frozen=True)
class EdgeList(Expr):
    """``Nbr[v]`` / ``In[v]`` / ``Out[v]`` — only as comprehension range."""

    direction: str  # "nbr" | "in" | "out"
    vertex: Expr


@dataclasses.dataclass(frozen=True)
class Reduce(Expr):
    """``func [ body | e <- range, filter_1, ..., filter_k ]``.

    ``func`` ∈ {minimum, maximum, sum, prod, and, or, count}; ``count`` is
    sugar for ``sum [1 | ...]``. ``argmin``/``argmax`` return the ``e.id`` of
    a minimizing/maximizing edge (used by matching algorithms).
    """

    func: str
    body: Expr
    edge_var: str
    range: EdgeList
    filters: Tuple[Expr, ...] = ()


# ---------------------------------------------------------------------------
# statements


class Stmt:
    __slots__ = ()


@dataclasses.dataclass(frozen=True)
class Let(Stmt):
    var: str
    value: Expr


@dataclasses.dataclass(frozen=True)
class If(Stmt):
    cond: Expr
    then: Tuple[Stmt, ...]
    other: Tuple[Stmt, ...] = ()


@dataclasses.dataclass(frozen=True)
class ForEdges(Stmt):
    """``for (e <- Nbr[v]) <block>`` — non-nested edge loop."""

    edge_var: str
    range: EdgeList
    body: Tuple[Stmt, ...]


@dataclasses.dataclass(frozen=True)
class LocalWrite(Stmt):
    """``local Field[v] op exp`` — v must be the current vertex."""

    field: str
    op: str  # ":=" "+=" "*=" "<?=" ">?=" "||=" "&&="
    value: Expr
    index_var: str = ""  # must name the step's vertex var (checked in analysis)


@dataclasses.dataclass(frozen=True)
class RemoteWrite(Stmt):
    """``remote Field[exp] op exp`` — accumulative op only."""

    field: str
    target: Expr
    op: str  # "+=" "*=" "<?=" ">?=" "||=" "&&="
    value: Expr


# ---------------------------------------------------------------------------
# programs


class Prog:
    __slots__ = ()


@dataclasses.dataclass(frozen=True)
class Step(Prog):
    """``for var in V <block> end`` — one algorithmic superstep."""

    vertex_var: str
    body: Tuple[Stmt, ...]


@dataclasses.dataclass(frozen=True)
class StopStep(Prog):
    """``stop var in V if exp`` — vertex inactivation (paper §3.4)."""

    vertex_var: str
    cond: Expr


@dataclasses.dataclass(frozen=True)
class Seq(Prog):
    progs: Tuple[Prog, ...]


@dataclasses.dataclass(frozen=True)
class Iter(Prog):
    """``do <prog> until fix [F1, ..., Fn]`` or ``until iter [k]``.

    The paper focuses on fixed-point termination but notes Palgol supports
    several kinds; fixed-trip-count iteration (``iter [k]``) is the one
    PageRank-style algorithms need.
    """

    body: Prog
    fix_fields: Tuple[str, ...]
    fixed_trips: Optional[int] = None


Program = Prog  # alias for readability at API boundaries


REMOTE_OPS = {"+=", "*=", "<?=", ">?=", "||=", "&&="}
LOCAL_OPS = {":="} | REMOTE_OPS
OP_TO_COMBINER = {
    "+=": "sum",
    "*=": "prod",
    "<?=": "min",
    ">?=": "max",
    "||=": "or",
    "&&=": "and",
}
REDUCE_FUNCS = {"minimum", "maximum", "sum", "prod", "and", "or", "count",
                "argmin", "argmax"}


def walk_exprs(node):
    """Yield every Expr reachable from an Expr/Stmt/Prog node."""
    if isinstance(node, Expr):
        yield node
        children = {
            Const: (),
            Var: (),
            EdgeProp: (),
            FieldAccess: (node.index,) if isinstance(node, FieldAccess) else (),
            Cond: (node.cond, node.then, node.other) if isinstance(node, Cond) else (),
            BinOp: (node.left, node.right) if isinstance(node, BinOp) else (),
            UnOp: (node.operand,) if isinstance(node, UnOp) else (),
            EdgeList: (node.vertex,) if isinstance(node, EdgeList) else (),
            Reduce: ((node.body, node.range) + node.filters)
            if isinstance(node, Reduce)
            else (),
        }[type(node)]
        for c in children:
            yield from walk_exprs(c)
    elif isinstance(node, Let):
        yield from walk_exprs(node.value)
    elif isinstance(node, If):
        yield from walk_exprs(node.cond)
        for s in node.then + node.other:
            yield from walk_exprs(s)
    elif isinstance(node, ForEdges):
        yield from walk_exprs(node.range)
        for s in node.body:
            yield from walk_exprs(s)
    elif isinstance(node, LocalWrite):
        yield from walk_exprs(node.value)
    elif isinstance(node, RemoteWrite):
        yield from walk_exprs(node.target)
        yield from walk_exprs(node.value)
    elif isinstance(node, Step):
        for s in node.body:
            yield from walk_exprs(s)
    elif isinstance(node, StopStep):
        yield from walk_exprs(node.cond)
    elif isinstance(node, Seq):
        for p in node.progs:
            yield from walk_exprs(p)
    elif isinstance(node, Iter):
        yield from walk_exprs(node.body)


def walk_stmts(stmts):
    """Yield statements recursively (pre-order)."""
    for s in stmts:
        yield s
        if isinstance(s, If):
            yield from walk_stmts(s.then)
            yield from walk_stmts(s.other)
        elif isinstance(s, ForEdges):
            yield from walk_stmts(s.body)
