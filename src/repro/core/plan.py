"""The superstep-plan IR: one canonical lowering of Palgol steps.

The paper's compilation story (§5) is a single expansion of each Palgol
step into Pregel supersteps: remote-reading supersteps that materialize the
chain-access buffers, one main (local-computation) superstep, and one
remote-updating superstep when the step has remote writes. This module is
the *only* place that expansion lives: :func:`lower_step` lowers a step to
a :class:`StepPlan` — a typed list of superstep ops — and every executor
consumes the plan instead of re-deriving it:

* the fused dense compiler (``repro.core.codegen.StepExecutor``) folds the
  op list into its single traced computation;
* the staged BSP executor (``repro.pregel.runtime``) dispatches one device
  call per op;
* the partitioned executor (``repro.graph.partition.executor``) maps each
  op onto its halo collective (``ReadRound`` → ``gather_global`` /
  ``halo_exchange``, ``RemoteUpdate`` → ``scatter_reduce``).

One op is one Pregel superstep, so ``len(plan.ops)`` *is* the step's
superstep cost — the STM cost models (``repro.core.stm``) count plan ops
directly, and accounting can never diverge from execution by construction.

Schedules
---------
``"pull"``
    The logic-system-derived one-sided schedule: chain patterns evaluate
    through the :class:`~repro.core.logic.PullSolver` gather DAG, one
    ``ReadRound`` per DAG depth (pointer doubling — ``D⁴`` in 2 rounds);
    neighborhood sends piggyback on the round after their chain is ready.
``"push"``
    The paper-faithful message-passing schedule (§4): chain patterns
    evaluate through the :class:`~repro.core.logic.PushSolver` derivation
    — requester addresses are forwarded along the chain while values
    double back, so ``D⁴`` costs 3 rounds instead of naive's 6. Rounds
    come in two kinds: ``push_request`` (address propagation only) and
    ``push_reply`` (a combined-reply round: the owner's value is sent
    once per combined request — Pregel message combining, the
    ``combiner`` op on the round — and materializes chain buffers).
    Neighborhood sends are the classic combined push along edges.
``"naive"``
    Hand-written-Pregel request/reply: every chain hop costs a *request*
    round (push requester ids to the owner — a real scatter) and a *reply*
    round (the owner returns the value), sequentially per pattern, plus one
    neighborhood-send round. The wire traffic manual code pays, with no
    message combining.
``"auto"``
    Per-step selection among the three: lower under every schedule and
    keep the cheapest plan. Without a :class:`ByteCostModel` the metric is
    the plan's own op count (the superstep cost model; ties go
    ``pull`` → ``push`` → ``naive``). With one, the metric is
    ``supersteps · superstep_overhead_bytes + plan_bytes(plan)`` — the
    byte-aware selection that lets naive/push win on tiny request sets at
    deep chains, following the channel-composition line of Zhang & Hu
    (1811.01669) and the combiner-driven push/pull knob of iPregel
    (2010.08781).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import ast
from repro.core.analysis import StepInfo, analyze_step
from repro.core.logic import Pattern, PullSolver, PushPlan, PushSolver

#: the halted-mask pseudo-field (paper §3.4); lives here so the plan IR's
#: read/write-set analysis and the executors share one spelling
HALTED = "_halted"

#: the schedules lower_step accepts
SCHEDULES = ("pull", "push", "naive", "auto")

#: schedules auto chooses among, in tie-break preference order
_AUTO_ORDER = ("pull", "push", "naive")


@dataclasses.dataclass(frozen=True)
class ChainEval:
    """One gather: materialize ``pattern`` as ``eval(suffix)[eval(prefix)]``.

    Both operands are already-materialized patterns (or axioms: ``()`` is
    the vertex id, a single field is a local array read). Pull rounds use
    the PullSolver's balanced split; naive hops always split off the last
    field (``prefix = pattern[:-1]``, ``suffix = (pattern[-1],)``); push
    rounds split at the derivation's chosen intermediate (``prefix = via``,
    ``suffix = pattern/via`` — the value the via-vertex ships back).
    """

    pattern: Pattern
    prefix: Pattern
    suffix: Pattern


@dataclasses.dataclass(frozen=True)
class PushSend:
    """One message flow of the push derivation completing this round:
    vertex ``via(u)`` sends ``expr(u)`` to vertex ``target(u)``
    (``expr = ()`` is the requester id — address propagation;
    ``target = ()`` is the requester itself — a value delivery).
    Recorded for wire accounting (:func:`plan_bytes`) and ``describe``;
    value deliveries also appear as the round's executable ``chains``.
    """

    target: Pattern
    expr: Pattern
    via: Pattern


@dataclasses.dataclass(frozen=True)
class ReadRound:
    """One remote-reading superstep.

    ``kind``:

    * ``"pull"`` — one pull-solver gather round (``chains`` are the DAG
      nodes at this depth; ``nbr_sends`` piggyback once their chain is
      ready);
    * ``"request"`` — naive hop, requester→owner address scatter for the
      single entry in ``chains`` (no value materialized);
    * ``"reply"`` — naive hop, owner→requester value gather (materializes
      ``chains[0].pattern``);
    * ``"nbr_send"`` — the naive schedule's neighborhood-send superstep
      (``nbr_sends`` only);
    * ``"push_request"`` — push round carrying only address propagation
      (``sends``; requester ids forwarded along the chain, combined per
      owner with ``combiner``);
    * ``"push_reply"`` — push round delivering values: ``chains`` are the
      buffers it materializes (one combined reply per distinct owner —
      message combining with ``combiner``), ``sends`` any piggybacked
      address flows, ``nbr_sends`` the combined neighborhood pushes.
    """

    kind: str
    chains: Tuple[ChainEval, ...] = ()
    nbr_sends: Tuple[Tuple[str, Pattern], ...] = ()  # (direction, pattern)
    sends: Tuple[PushSend, ...] = ()  # push message flows (accounting)
    combiner: Optional[str] = None  # message-combining op on push rounds
    general: int = 0  # general-read conversation legs riding this round


@dataclasses.dataclass(frozen=True)
class MainCompute:
    """The main superstep: local computation + emitting remote writes."""

    emits_remote: bool = False


@dataclasses.dataclass(frozen=True)
class RemoteUpdate:
    """The remote-updating superstep: apply combined messages at owners."""

    writes: Tuple[Tuple[str, str], ...]  # (field, op) in program order


PlanOp = object  # ReadRound | MainCompute | RemoteUpdate

#: ReadRound kinds that materialize their ``chains`` as value buffers
VALUE_KINDS = ("pull", "reply", "push_reply")

#: ReadRound kinds that carry addresses only (no buffer materialized)
REQUEST_KINDS = ("request", "push_request")


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """A Palgol step lowered to its superstep op list.

    ``schedule`` is the *resolved* schedule (``pull``/``push``/``naive``);
    ``requested`` records what the caller asked for (may be ``auto``).
    """

    step: ast.Step
    info: StepInfo
    schedule: str
    requested: str
    ops: Tuple[PlanOp, ...]

    @property
    def n_supersteps(self) -> int:
        """Superstep cost of one execution of this step — the accounting
        contract: one op is one superstep."""
        return len(self.ops)

    @property
    def read_rounds(self) -> int:
        return sum(1 for op in self.ops if isinstance(op, ReadRound))

    @property
    def has_remote_update(self) -> bool:
        return any(isinstance(op, RemoteUpdate) for op in self.ops)

    @property
    def materialized(self) -> Tuple[Pattern, ...]:
        """Every chain pattern some ReadRound materializes (mailbox keys of
        the staged executor), in materialization order."""
        out: List[Pattern] = []
        for op in self.ops:
            if isinstance(op, ReadRound) and op.kind in VALUE_KINDS:
                out.extend(ce.pattern for ce in op.chains)
        return tuple(dict.fromkeys(out))

    def describe(self) -> str:
        """Compact one-line rendering for dry-runs and logs."""
        parts = []
        for op in self.ops:
            if isinstance(op, ReadRound):
                items = [".".join(ce.pattern) for ce in op.chains]
                items += [
                    f"@{'.'.join(s.target) or 'u'}<-{'.'.join(s.expr) or 'Id'}"
                    for s in op.sends
                ]
                items += [f"{d}:{'.'.join(p) or 'Id'}" for d, p in op.nbr_sends]
                parts.append(f"RR[{op.kind}{' ' if items else ''}{' '.join(items)}]")
            elif isinstance(op, MainCompute):
                parts.append("Main")
            else:
                parts.append(
                    "RU[" + " ".join(f"{f}{o}" for f, o in op.writes) + "]"
                )
        return " -> ".join(parts)


def remote_write_descs(step: ast.Step) -> Tuple[Tuple[str, str], ...]:
    """(field, op) of every remote write, in static program order — the
    message-descriptor contract between MainCompute and RemoteUpdate."""
    return tuple(
        (s.field, s.op)
        for s in ast.walk_stmts(step.body)
        if isinstance(s, ast.RemoteWrite)
    )


def _tail(ops: List[PlanOp], step: ast.Step, info: StepInfo) -> List[PlanOp]:
    ops.append(MainCompute(emits_remote=info.has_remote_writes()))
    if info.has_remote_writes():
        ops.append(RemoteUpdate(writes=remote_write_descs(step)))
    return ops


def _lower_pull(step: ast.Step, info: StepInfo) -> List[PlanOp]:
    ops: List[PlanOp] = []
    pats = info.read_patterns()
    # general (computed-index) reads inline their gather into an existing
    # round's dispatch, but still cost at least one remote-reading
    # superstep (pull_read_rounds' floor) — a step with only general reads
    # gets one chain-less round
    if pats or info.nbr_comms or info.general_reads:
        solver = PullSolver()
        order = solver.schedule(pats)
        depth = {p: solver.solve(p).rounds for p in order}
        total_rounds = info.pull_read_rounds()
        # neighborhood sends fire at round rounds(pattern)+1
        nbr_round = {
            (d, p): solver.rounds(p) + 1 for d, p in info.nbr_comms
        }
        for r in range(1, total_rounds + 1):
            chains = tuple(
                ChainEval(
                    p,
                    solver.solve(p).prefix.pattern,
                    solver.solve(p).suffix.pattern,
                )
                for p in order
                if depth.get(p) == r and len(p) > 1
            )
            sends = tuple(sorted(k for k, rr in nbr_round.items() if rr == r))
            ops.append(ReadRound("pull", chains, sends))
    return _tail(ops, step, info)


def _lower_naive(step: ast.Step, info: StepInfo) -> List[PlanOp]:
    ops: List[PlanOp] = []
    for p in info.read_patterns():
        for k in range(2, len(p) + 1):
            prefix = p[:k]
            hop = ChainEval(prefix, prefix[:-1], (prefix[-1],))
            ops.append(ReadRound("request", (hop,)))
            ops.append(ReadRound("reply", (hop,)))
    # each general (computed-index) read is one request/reply conversation
    # in manual code; the value itself is consumed inline in the main
    # superstep, so the rounds carry no chains — they cost supersteps only
    for _ in range(info.general_reads):
        ops.append(ReadRound("request"))
        ops.append(ReadRound("reply"))
    if info.nbr_comms:
        ops.append(ReadRound("nbr_send", (), tuple(sorted(info.nbr_comms))))
    return _tail(ops, step, info)


def _collect_push_sends(
    plan: PushPlan, out: Dict[Tuple[Pattern, Pattern], Tuple[int, Pattern]]
):
    """Walk a chosen PushPlan derivation, recording every non-axiom send as
    (target, expr) → (completion round, via). Shared sub-derivations dedup
    (the solver memo already shares them across patterns)."""
    if plan.rounds <= 0 or plan.via is None:
        return
    key = (plan.target, plan.expr)
    if key not in out or out[key][0] > plan.rounds:
        out[key] = (plan.rounds, plan.via)
    _collect_push_sends(plan.value_plan, out)
    _collect_push_sends(plan.addr_plan, out)


def _lower_push(step: ast.Step, info: StepInfo) -> List[PlanOp]:
    """The paper-faithful push expansion (§4.1.1 message passing).

    Chain materializations follow the PushSolver derivation: the value
    ``K_u p`` completes at round ``rounds(p)`` via intermediate ``w``, and
    the executable realization is the gather ``eval(p/w)[eval(w)]`` — so
    the via-prefix ``w`` is (recursively) scheduled for materialization
    too. For every chain pattern up to depth 8 this reproduces the
    solver's minimal round count exactly (property-tested); the defensive
    ``max`` below only extends the plan if a prefix materialization ever
    lagged its consumer, keeping the lowering correct even then.
    """
    solver = PushSolver()
    mat_round: Dict[Pattern, int] = {}
    via_of: Dict[Pattern, Pattern] = {}

    def want(p: Pattern) -> int:
        if len(p) <= 1:
            return 0
        if p in mat_round:
            return mat_round[p]
        plan = solver.solve((), p)
        via = plan.via
        r = plan.rounds
        for dep in (via, p[len(via):]):
            r = max(r, want(dep) + 1)
        mat_round[p] = r
        via_of[p] = via
        return r

    for p in info.read_patterns():
        want(p)

    # message flows of the chosen derivations, for wire accounting
    send_round: Dict[Tuple[Pattern, Pattern], Tuple[int, Pattern]] = {}
    for p in info.read_patterns():
        _collect_push_sends(solver.solve((), p), send_round)

    total = max([0] + list(mat_round.values()))
    # the neighborhood send is the classic combined Pregel push along
    # edges: it fires once the sender's chain value is materialized
    nbr_round = {
        (d, p): mat_round.get(p, 0) + 1 for d, p in info.nbr_comms
    }
    if nbr_round:
        total = max(total, max(nbr_round.values()))
    if info.general_reads:
        # one combined request/reply conversation; independent flows share
        # supersteps, so it contributes rounds 1–2 (paper's parallel flows)
        total = max(total, 2)

    ops: List[PlanOp] = []
    for r in range(1, total + 1):
        chains = tuple(
            ChainEval(p, via_of[p], p[len(via_of[p]):])
            for p in sorted(mat_round)
            if mat_round[p] == r
        )
        sends = tuple(
            PushSend(t, e, via)
            for (t, e), (rr, via) in sorted(send_round.items())
            if rr == r and t != ()  # value deliveries are the chains above
        )
        nbrs = tuple(sorted(k for k, rr in nbr_round.items() if rr == r))
        # general-read conversations ride rounds 1 (request) and 2 (reply)
        general = info.general_reads if r <= 2 else 0
        carries_values = bool(chains or nbrs or (r == 2 and general))
        kind = "push_reply" if carries_values else "push_request"
        ops.append(
            ReadRound(kind, chains, nbrs, sends, combiner="min",
                      general=general)
        )
    return _tail(ops, step, info)


_LOWERERS = {
    "pull": _lower_pull,
    "push": _lower_push,
    "naive": _lower_naive,
}


# ---------------------------------------------------------------------------
# per-op byte estimates + the byte-aware auto selector


@dataclasses.dataclass(frozen=True)
class ByteCostModel:
    """Per-round byte estimates for plan selection and reporting.

    All figures are aggregate across devices, for one value-width field.

    * ``n_vertices`` — full array width: what a pull round's one-sided
      gather ships (pointer doubling materializes intermediates at *every*
      vertex, so its request set cannot shrink);
    * ``request_set`` — live requesters per naive hop (≤ N; measured from
      the active set / halted mask, or the partition halo as a boundary
      proxy). Naive pays one request + one reply message per requester;
    * ``combined_request_set`` — requesters after message combining (push:
      one slot per distinct owner). Defaults to ``request_set`` (no
      combining advantage assumed until measured);
    * ``halo_bytes`` — one static neighborhood exchange
      (:func:`repro.graph.partition.stats.partition_stats` halo payload);
    * ``update_bytes`` — one RemoteUpdate reduce-scatter;
    * ``reply_width`` — values per reply payload (multi-field chains);
    * ``superstep_overhead_bytes`` — byte-equivalent of one superstep's
      fixed latency (barrier + dispatch); what ``auto`` charges per op on
      top of the wire bytes.
    """

    n_vertices: int
    value_bytes: int = 4
    request_set: Optional[int] = None
    combined_request_set: Optional[int] = None
    halo_bytes: Optional[int] = None
    update_bytes: Optional[int] = None
    reply_width: int = 1
    superstep_overhead_bytes: int = 0

    def resolved(self) -> "ByteCostModel":
        """Fill defaults: request_set→N, combined→request_set,
        halo/update→N values (replicated-dense worst case). Request sets
        clamp to N — each vertex issues at most one chain request per hop,
        so a measured proxy larger than N (e.g. a power-law halo) caps."""
        n = self.n_vertices
        b = self.value_bytes
        req = self.request_set if self.request_set is not None else n
        req = min(req, n)
        comb = (
            self.combined_request_set
            if self.combined_request_set is not None
            else req
        )
        comb = min(comb, req)
        halo = self.halo_bytes if self.halo_bytes is not None else n * b
        upd = self.update_bytes if self.update_bytes is not None else n * b
        return dataclasses.replace(
            self,
            request_set=req,
            combined_request_set=comb,
            halo_bytes=halo,
            update_bytes=upd,
        )


def op_bytes(op: PlanOp, costs: ByteCostModel) -> int:
    """Estimated wire bytes of one plan op under ``costs`` (resolved).

    * pull round: each chain is an array-wide one-sided gather — N ids out,
      N·reply_width values back; neighborhood sends ride the static halo;
    * naive request/reply: one message per live requester, uncombined;
    * push request/reply: one message per *combined* request slot
      (message combining), address flows (``sends``) ship combined ids;
    * MainCompute is wire-free; RemoteUpdate is one combined scatter.
    """
    b = costs.value_bytes
    if isinstance(op, MainCompute):
        return 0
    if isinstance(op, RemoteUpdate):
        return costs.update_bytes
    total = 0
    if op.kind == "pull":
        for _ in op.chains:
            total += costs.n_vertices * b * (1 + costs.reply_width)
    elif op.kind == "request":
        total += max(1, len(op.chains)) * costs.request_set * b
    elif op.kind == "reply":
        total += (
            max(1, len(op.chains))
            * costs.request_set
            * costs.reply_width
            * b
        )
    elif op.kind == "push_request":
        total += (
            max(1, len(op.sends) + op.general)
            * costs.combined_request_set
            * b
        )
    elif op.kind == "push_reply":
        total += (
            len(op.chains) * costs.combined_request_set * costs.reply_width * b
        )
        total += len(op.sends) * costs.combined_request_set * b
        # general-read conversation legs riding this round (combined)
        total += op.general * costs.combined_request_set * costs.reply_width * b
    for _ in op.nbr_sends:
        total += costs.halo_bytes
    return total


def plan_bytes(plan: StepPlan, costs: ByteCostModel) -> int:
    """Total estimated wire bytes of one execution of ``plan``."""
    costs = costs.resolved()
    return sum(op_bytes(op, costs) for op in plan.ops)


def plan_score(plan: StepPlan, costs: Optional[ByteCostModel]) -> Tuple:
    """The auto-selection metric. Without costs: op count (the plan's own
    superstep cost model). With costs: supersteps charged at the fixed
    per-superstep overhead plus the modeled wire bytes."""
    if costs is None:
        return (plan.n_supersteps,)
    costs = costs.resolved()
    return (
        plan.n_supersteps * costs.superstep_overhead_bytes
        + plan_bytes(plan, costs),
        plan.n_supersteps,
    )


def program_plan_records(step_plans, costs: Optional[ByteCostModel] = None):
    """JSON-ready records for ``CompiledProgram.step_plans()`` output — the
    one rendering the benchmark report and the partition dry-run share.
    With a :class:`ByteCostModel`, each record also carries the modeled
    per-execution wire bytes."""
    out = []
    for _, plan in step_plans:
        rec = {
            "resolved": plan.schedule,
            "read_rounds": plan.read_rounds,
            "supersteps": plan.n_supersteps,
            "ops": plan.describe(),
        }
        if costs is not None:
            rec["bytes"] = plan_bytes(plan, costs)
        out.append(rec)
    return out


def lower_step(
    step: ast.Step,
    info: Optional[StepInfo] = None,
    schedule: str = "pull",
    byte_costs: Optional[ByteCostModel] = None,
) -> StepPlan:
    """Lower a Palgol step to its :class:`StepPlan` under ``schedule``.

    The one canonical superstep expansion — every executor and the STM
    cost models consume this. ``byte_costs`` only affects ``"auto"``:
    the selector then ranks candidate plans by
    :func:`plan_score` (supersteps·overhead + modeled bytes) instead of
    bare op count.
    """
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; expected one of {SCHEDULES}"
        )
    info = info if info is not None else analyze_step(step)
    if schedule == "auto":
        candidates = [
            StepPlan(step, info, s, "auto", tuple(_LOWERERS[s](step, info)))
            for s in _AUTO_ORDER
        ]
        # stable min: ties keep the earlier (pull-first) candidate
        return min(candidates, key=lambda p: plan_score(p, byte_costs))
    ops = _LOWERERS[schedule](step, info)
    return StepPlan(step, info, schedule, schedule, tuple(ops))


# ---------------------------------------------------------------------------
# the whole-program plan: lower_program + the §4.3 fuse pass
#
# ``lower_step`` expands ONE step; a Palgol program is a Seq/Iter tree of
# steps, and the paper's headline optimizations (§4.3 state merging and
# iteration fusion) only exist at that program level. ``lower_program``
# lowers every step and linearizes the tree into a :class:`ProgramPlan` —
# ``Superstep`` items (one device dispatch each) and ``PlanLoop`` items
# (host-checked fixed points) — and :func:`fuse` rewrites it so the
# optimized schedule is what the executors actually dispatch. The STM cost
# models (``repro.core.stm``) count the same fused items, so optimized
# accounting equals optimized execution by construction — the program-level
# twin of the per-step invariant ``len(plan.ops) == supersteps``.


@dataclasses.dataclass(frozen=True)
class IterInit:
    """The iteration Init superstep (paper Fig. 11): sets up the
    OR-aggregator for the first termination check. No field reads/writes,
    so it merges freely and is the landing pad for the fused loop's
    prefetched first ReadRound."""


@dataclasses.dataclass(frozen=True)
class StopOp:
    """One StopStep superstep: evaluate the condition, flip the halted
    mask (writes :data:`HALTED` only)."""

    stop: ast.StopStep


@dataclasses.dataclass(frozen=True)
class OpRef:
    """One primitive plan op with its owning step context.

    ``plan`` is the owning :class:`StepPlan` (None for IterInit/StopOp);
    ``sidx`` is the step ordinal in program order — the executors' mailbox
    namespace, so two steps materializing the same chain pattern cannot
    collide once supersteps from different steps share a program-level
    mailbox.
    """

    op: object  # ReadRound | MainCompute | RemoteUpdate | IterInit | StopOp
    plan: Optional[StepPlan] = None
    sidx: int = -1


@dataclasses.dataclass(frozen=True)
class Superstep:
    """One fused Pregel superstep: its parts execute *in order* inside one
    dispatch. Sequencing is the fusion-correctness argument: a merged
    superstep runs exactly the primitive op sequence the unfused plan runs,
    only the dispatch boundaries move — so fused execution bit-matches
    unfused by construction. ``head`` marks the first superstep of its
    program node (the only legal merge target, as in §4.3.1)."""

    parts: Tuple[OpRef, ...]
    head: bool = False

    def describe(self) -> str:
        names = []
        for ref in self.parts:
            op = ref.op
            if isinstance(op, ReadRound):
                names.append(f"RR[{op.kind}]")
            elif isinstance(op, MainCompute):
                names.append("Main")
            elif isinstance(op, RemoteUpdate):
                names.append("RU")
            elif isinstance(op, IterInit):
                names.append("Init")
            else:
                names.append("Stop")
        return "+".join(names)


@dataclasses.dataclass(frozen=True)
class PlanLoop:
    """A fixed-point / fixed-trip iteration: ``body`` items execute per
    trip; ``fused`` records whether the §4.3.2 loop-back fusion fired (the
    body's first ReadRound was duplicated into the preceding superstep and
    merged into the body's last superstep)."""

    body: Tuple[object, ...]  # Superstep | PlanLoop
    node: ast.Iter
    iter_index: int
    fused: bool = False


@dataclasses.dataclass(frozen=True)
class ProgramPlan:
    """The whole Palgol program as an executable superstep schedule."""

    prog: ast.Prog
    schedule: str
    items: Tuple[object, ...]  # Superstep | PlanLoop
    fused: bool
    step_plans: Tuple[Tuple[ast.Step, StepPlan], ...]

    def cost(self) -> Tuple[int, Dict[int, int], List[str]]:
        """``(base, per_iter, detail)`` — supersteps as a linear functional
        of the trip counts, counted off the very items the executors walk
        (the STM :class:`~repro.core.stm.CostModel` wraps this)."""
        base = [0]
        per_iter: Dict[int, int] = {}
        detail: List[str] = []

        def count(items, key):
            for it in items:
                if isinstance(it, Superstep):
                    if key is None:
                        base[0] += 1
                    else:
                        per_iter[key] = per_iter.get(key, 0) + 1
                else:
                    count(it.body, it.iter_index)

        count(self.items, None)
        for it in self.items:
            detail.extend(_loop_details(it))
        return base[0], per_iter, detail

    def describe(self) -> List[str]:
        """One line per item, loops indented — the dry-run rendering."""
        out: List[str] = []

        def go(items, depth):
            pad = "  " * depth
            for it in items:
                if isinstance(it, Superstep):
                    out.append(pad + it.describe())
                else:
                    out.append(
                        pad + f"loop#{it.iter_index} (fused={it.fused}):"
                    )
                    go(it.body, depth + 1)

        go(self.items, 0)
        return out


def _loop_details(item, out=None) -> List[str]:
    out = [] if out is None else out
    if isinstance(item, PlanLoop):
        n = sum(1 for b in item.body if isinstance(b, Superstep))
        out.append(
            f"loop#{item.iter_index}: {n} supersteps/iter "
            f"(fused={item.fused})"
        )
        for b in item.body:
            _loop_details(b, out)
    return out


def iter_nodes(prog: ast.Prog) -> List[ast.Iter]:
    """Pre-order list of Iter nodes — the iteration-counter index order
    shared by the compiler's trips vector and the cost models."""
    out: List[ast.Iter] = []

    def go(p):
        if isinstance(p, ast.Seq):
            for q in p.progs:
                go(q)
        elif isinstance(p, ast.Iter):
            out.append(p)
            go(p.body)

    go(prog)
    return out


def lower_program(
    prog: ast.Prog,
    schedule: str = "pull",
    byte_costs: Optional[ByteCostModel] = None,
) -> ProgramPlan:
    """Lower a whole Palgol program to its (unfused) :class:`ProgramPlan`:
    one single-part :class:`Superstep` per plan op — exactly the expansion
    the staged executor has always dispatched. Apply :func:`fuse` for the
    §4.3-optimized schedule."""
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; expected one of {SCHEDULES}"
        )
    loop_idx = {id(node): i for i, node in enumerate(iter_nodes(prog))}
    sidx = [0]
    plans: List[Tuple[ast.Step, StepPlan]] = []

    def lower(p) -> List[object]:
        if isinstance(p, ast.Step):
            plan = lower_step(p, schedule=schedule, byte_costs=byte_costs)
            si = sidx[0]
            sidx[0] += 1
            plans.append((p, plan))
            return [
                Superstep((OpRef(op, plan, si),), head=(i == 0))
                for i, op in enumerate(plan.ops)
            ]
        if isinstance(p, ast.StopStep):
            return [Superstep((OpRef(StopOp(p)),), head=True)]
        if isinstance(p, ast.Seq):
            out: List[object] = []
            for q in p.progs:
                out.extend(lower(q))
            return out
        if isinstance(p, ast.Iter):
            body = lower(p.body)
            return [
                Superstep((OpRef(IterInit()),), head=True),
                PlanLoop(tuple(body), p, loop_idx[id(p)], fused=False),
            ]
        raise TypeError(f"unknown program node {type(p).__name__}")

    items = tuple(lower(prog))
    return ProgramPlan(
        prog=prog,
        schedule=schedule,
        items=items,
        fused=False,
        step_plans=tuple(plans),
    )


def _op_writes(ref: OpRef) -> frozenset:
    """Fields the op writes within its superstep."""
    op = ref.op
    if isinstance(op, MainCompute):
        return frozenset(ref.plan.info.local_write_fields)
    if isinstance(op, RemoteUpdate):
        return frozenset(f for f, _ in op.writes)
    if isinstance(op, StopOp):
        return frozenset((HALTED,))
    return frozenset()  # ReadRound / IterInit: mailbox only


def _round_reads(ref: OpRef) -> frozenset:
    """Fields whose pre-superstep values a ReadRound's gathers/sends read
    (every field named in its chain / neighborhood / address patterns;
    general computed-index reads over-approximate to the step's full read
    set — the safe direction: a too-big set only withholds a merge)."""
    op = ref.op
    fields = set()
    for ce in op.chains:
        fields.update(ce.pattern)
    for _, pat in op.nbr_sends:
        fields.update(pat)
    for s in op.sends:
        fields.update(s.target)
        fields.update(s.expr)
        fields.update(s.via)
    if op.general and ref.plan is not None:
        fields.update(ref.plan.info.fields_read)
    return frozenset(fields)


def _merge_ok(prev: Superstep, nxt: Superstep) -> bool:
    """§4.3.1 state-merging legality at a program-node boundary.

    The paper's condition is message independence: the next node's first
    superstep must not consume messages produced inside the merged
    superstep. A leading MainCompute (a step with no remote reads), a
    StopStep, or an iteration Init consumes no messages — they merge
    unconditionally. A leading ReadRound *initiates* communication whose
    request set / payload is read from field state; we additionally require
    its read set to be disjoint from everything the previous superstep
    writes, so every fused op's outgoing communication is derivable from
    pre-superstep state (the conservative refinement that keeps merged
    collectives combinable in the partitioned executor)."""
    first = nxt.parts[0]
    if not isinstance(first.op, ReadRound):
        return True
    writes = frozenset().union(*(_op_writes(p) for p in prev.parts))
    return not (writes & _round_reads(first))


def fuse(pp: ProgramPlan) -> ProgramPlan:
    """The §4.3 optimization pass, applied for real.

    * **state merging** (§4.3.1): at every program-node boundary, the
      previous node's trailing superstep absorbs the next node's first
      superstep when :func:`_merge_ok` holds (merges chain, so a run of
      one-superstep steps collapses into one superstep);
    * **iteration fusion** (§4.3.2): a loop whose body begins with a
      ReadRound has that round duplicated into the preceding superstep
      (the prefetch) and merged into the body's last superstep — the
      loop-back edge overlaps the round with the previous iteration's
      tail, saving one superstep per iteration. The prefetch executes
      *after* the tail's ops, so it reads exactly the next iteration's
      input state; nested loops keep an explicit init (no fusion), as in
      the paper.

    Executors walk the returned plan directly; since parts stay in
    primitive-op order, fused execution is the unfused op sequence with
    different dispatch boundaries (plus one discarded trailing prefetch
    per fused loop) — bit-identical results, fewer supersteps.
    """

    def fuse_items(items) -> List[object]:
        out: List[object] = []
        for it in items:
            if isinstance(it, PlanLoop):
                body = fuse_items(list(it.body))
                fused_loop = False
                if (
                    not any(isinstance(b, PlanLoop) for b in body)
                    and len(body) >= 2
                    and isinstance(body[0], Superstep)
                    and len(body[0].parts) == 1
                    and isinstance(body[0].parts[0].op, ReadRound)
                    and out
                    and isinstance(out[-1], Superstep)
                ):
                    s1 = body[0].parts[0]
                    last = body[-1]
                    body = body[1:-1] + [
                        Superstep(last.parts + (s1,), last.head)
                    ]
                    out[-1] = Superstep(out[-1].parts + (s1,), out[-1].head)
                    fused_loop = True
                out.append(
                    dataclasses.replace(
                        it, body=tuple(body), fused=fused_loop
                    )
                )
            else:
                if (
                    out
                    and isinstance(out[-1], Superstep)
                    and it.head
                    and _merge_ok(out[-1], it)
                ):
                    out[-1] = Superstep(
                        out[-1].parts + it.parts, out[-1].head
                    )
                else:
                    out.append(it)
        return out

    return dataclasses.replace(
        pp, items=tuple(fuse_items(list(pp.items))), fused=True
    )
