"""The superstep-plan IR: one canonical lowering of Palgol steps.

The paper's compilation story (§5) is a single expansion of each Palgol
step into Pregel supersteps: remote-reading supersteps that materialize the
chain-access buffers, one main (local-computation) superstep, and one
remote-updating superstep when the step has remote writes. This module is
the *only* place that expansion lives: :func:`lower_step` lowers a step to
a :class:`StepPlan` — a typed list of superstep ops — and every executor
consumes the plan instead of re-deriving it:

* the fused dense compiler (``repro.core.codegen.StepExecutor``) folds the
  op list into its single traced computation;
* the staged BSP executor (``repro.pregel.runtime``) dispatches one device
  call per op;
* the partitioned executor (``repro.graph.partition.executor``) maps each
  op onto its halo collective (``ReadRound`` → ``gather_global`` /
  ``halo_exchange``, ``RemoteUpdate`` → ``scatter_reduce``).

One op is one Pregel superstep, so ``len(plan.ops)`` *is* the step's
superstep cost — the STM cost models (``repro.core.stm``) count plan ops
directly, and accounting can never diverge from execution by construction.

Schedules
---------
``"pull"``
    The logic-system-derived one-sided schedule: chain patterns evaluate
    through the :class:`~repro.core.logic.PullSolver` gather DAG, one
    ``ReadRound`` per DAG depth (pointer doubling — ``D⁴`` in 2 rounds);
    neighborhood sends piggyback on the round after their chain is ready.
``"naive"``
    Hand-written-Pregel request/reply: every chain hop costs a *request*
    round (push requester ids to the owner — a real scatter) and a *reply*
    round (the owner returns the value), sequentially per pattern, plus one
    neighborhood-send round. The wire traffic manual code pays.
``"auto"``
    Per-step selection: lower under both schedules and keep the plan with
    fewer ops (ties go to ``pull``). This is the STM-cost-driven choice —
    the plan's own op count is the superstep cost model — following the
    channel-composition line of Zhang & Hu (1811.01669) and the push/pull
    selection knob of iPregel (2010.08781).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core import ast
from repro.core.analysis import StepInfo, analyze_step
from repro.core.logic import Pattern, PullSolver

#: the schedules lower_step accepts
SCHEDULES = ("pull", "naive", "auto")


@dataclasses.dataclass(frozen=True)
class ChainEval:
    """One gather: materialize ``pattern`` as ``eval(suffix)[eval(prefix)]``.

    Both operands are already-materialized patterns (or axioms: ``()`` is
    the vertex id, a single field is a local array read). Pull rounds use
    the PullSolver's balanced split; naive hops always split off the last
    field (``prefix = pattern[:-1]``, ``suffix = (pattern[-1],)``).
    """

    pattern: Pattern
    prefix: Pattern
    suffix: Pattern


@dataclasses.dataclass(frozen=True)
class ReadRound:
    """One remote-reading superstep.

    ``kind``:

    * ``"pull"`` — one pull-solver gather round (``chains`` are the DAG
      nodes at this depth; ``nbr_sends`` piggyback once their chain is
      ready);
    * ``"request"`` — naive hop, requester→owner address scatter for the
      single entry in ``chains`` (no value materialized);
    * ``"reply"`` — naive hop, owner→requester value gather (materializes
      ``chains[0].pattern``);
    * ``"nbr_send"`` — the naive schedule's neighborhood-send superstep
      (``nbr_sends`` only).
    """

    kind: str
    chains: Tuple[ChainEval, ...] = ()
    nbr_sends: Tuple[Tuple[str, Pattern], ...] = ()  # (direction, pattern)


@dataclasses.dataclass(frozen=True)
class MainCompute:
    """The main superstep: local computation + emitting remote writes."""

    emits_remote: bool = False


@dataclasses.dataclass(frozen=True)
class RemoteUpdate:
    """The remote-updating superstep: apply combined messages at owners."""

    writes: Tuple[Tuple[str, str], ...]  # (field, op) in program order


PlanOp = object  # ReadRound | MainCompute | RemoteUpdate


@dataclasses.dataclass(frozen=True)
class StepPlan:
    """A Palgol step lowered to its superstep op list.

    ``schedule`` is the *resolved* schedule (``pull``/``naive``);
    ``requested`` records what the caller asked for (may be ``auto``).
    """

    step: ast.Step
    info: StepInfo
    schedule: str
    requested: str
    ops: Tuple[PlanOp, ...]

    @property
    def n_supersteps(self) -> int:
        """Superstep cost of one execution of this step — the accounting
        contract: one op is one superstep."""
        return len(self.ops)

    @property
    def read_rounds(self) -> int:
        return sum(1 for op in self.ops if isinstance(op, ReadRound))

    @property
    def has_remote_update(self) -> bool:
        return any(isinstance(op, RemoteUpdate) for op in self.ops)

    @property
    def materialized(self) -> Tuple[Pattern, ...]:
        """Every chain pattern some ReadRound materializes (mailbox keys of
        the staged executor), in materialization order."""
        out: List[Pattern] = []
        for op in self.ops:
            if isinstance(op, ReadRound) and op.kind in ("pull", "reply"):
                out.extend(ce.pattern for ce in op.chains)
        return tuple(dict.fromkeys(out))

    def describe(self) -> str:
        """Compact one-line rendering for dry-runs and logs."""
        parts = []
        for op in self.ops:
            if isinstance(op, ReadRound):
                items = [".".join(ce.pattern) for ce in op.chains]
                items += [f"{d}:{'.'.join(p) or 'Id'}" for d, p in op.nbr_sends]
                parts.append(f"RR[{op.kind}{' ' if items else ''}{' '.join(items)}]")
            elif isinstance(op, MainCompute):
                parts.append("Main")
            else:
                parts.append(
                    "RU[" + " ".join(f"{f}{o}" for f, o in op.writes) + "]"
                )
        return " -> ".join(parts)


def remote_write_descs(step: ast.Step) -> Tuple[Tuple[str, str], ...]:
    """(field, op) of every remote write, in static program order — the
    message-descriptor contract between MainCompute and RemoteUpdate."""
    return tuple(
        (s.field, s.op)
        for s in ast.walk_stmts(step.body)
        if isinstance(s, ast.RemoteWrite)
    )


def _tail(ops: List[PlanOp], step: ast.Step, info: StepInfo) -> List[PlanOp]:
    ops.append(MainCompute(emits_remote=info.has_remote_writes()))
    if info.has_remote_writes():
        ops.append(RemoteUpdate(writes=remote_write_descs(step)))
    return ops


def _lower_pull(step: ast.Step, info: StepInfo) -> List[PlanOp]:
    ops: List[PlanOp] = []
    pats = info.read_patterns()
    # general (computed-index) reads inline their gather into an existing
    # round's dispatch, but still cost at least one remote-reading
    # superstep (pull_read_rounds' floor) — a step with only general reads
    # gets one chain-less round
    if pats or info.nbr_comms or info.general_reads:
        solver = PullSolver()
        order = solver.schedule(pats)
        depth = {p: solver.solve(p).rounds for p in order}
        total_rounds = info.pull_read_rounds()
        # neighborhood sends fire at round rounds(pattern)+1
        nbr_round = {
            (d, p): solver.rounds(p) + 1 for d, p in info.nbr_comms
        }
        for r in range(1, total_rounds + 1):
            chains = tuple(
                ChainEval(
                    p,
                    solver.solve(p).prefix.pattern,
                    solver.solve(p).suffix.pattern,
                )
                for p in order
                if depth.get(p) == r and len(p) > 1
            )
            sends = tuple(sorted(k for k, rr in nbr_round.items() if rr == r))
            ops.append(ReadRound("pull", chains, sends))
    return _tail(ops, step, info)


def _lower_naive(step: ast.Step, info: StepInfo) -> List[PlanOp]:
    ops: List[PlanOp] = []
    for p in info.read_patterns():
        for k in range(2, len(p) + 1):
            prefix = p[:k]
            hop = ChainEval(prefix, prefix[:-1], (prefix[-1],))
            ops.append(ReadRound("request", (hop,)))
            ops.append(ReadRound("reply", (hop,)))
    # each general (computed-index) read is one request/reply conversation
    # in manual code; the value itself is consumed inline in the main
    # superstep, so the rounds carry no chains — they cost supersteps only
    for _ in range(info.general_reads):
        ops.append(ReadRound("request"))
        ops.append(ReadRound("reply"))
    if info.nbr_comms:
        ops.append(ReadRound("nbr_send", (), tuple(sorted(info.nbr_comms))))
    return _tail(ops, step, info)


def program_plan_records(step_plans) -> List[dict]:
    """JSON-ready records for ``CompiledProgram.step_plans()`` output — the
    one rendering the benchmark report and the partition dry-run share."""
    return [
        {
            "resolved": plan.schedule,
            "read_rounds": plan.read_rounds,
            "supersteps": plan.n_supersteps,
            "ops": plan.describe(),
        }
        for _, plan in step_plans
    ]


def lower_step(
    step: ast.Step,
    info: Optional[StepInfo] = None,
    schedule: str = "pull",
) -> StepPlan:
    """Lower a Palgol step to its :class:`StepPlan` under ``schedule``.

    The one canonical superstep expansion — every executor and the STM
    cost models consume this.
    """
    if schedule not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {schedule!r}; expected one of {SCHEDULES}"
        )
    info = info if info is not None else analyze_step(step)
    if schedule == "auto":
        pull = StepPlan(step, info, "pull", "auto", tuple(_lower_pull(step, info)))
        naive = StepPlan(
            step, info, "naive", "auto", tuple(_lower_naive(step, info))
        )
        # the plan's own op count IS the superstep cost model; ties → pull
        return pull if pull.n_supersteps <= naive.n_supersteps else naive
    ops = _lower_pull(step, info) if schedule == "pull" else _lower_naive(step, info)
    return StepPlan(step, info, schedule, schedule, tuple(ops))
