"""Standard library of Palgol programs (paper §5.3's algorithm list).

Each entry is Palgol *source text* — parsed, analyzed, and compiled like any
user program. Coverage vs the paper's Table:

| algorithm | status |
|---|---|
| PageRank (PR)                     | ``PAGERANK`` |
| Single-Source Shortest Path       | ``SSSP`` |
| Shiloach-Vishkin connectivity     | ``SV`` (paper Fig. 6 verbatim) |
| Weakly Connected Components       | ``WCC`` (HashMin) |
| Randomized Bipartite Matching     | ``BIPARTITE_MATCHING`` (priority-det.) |
| Approx. Max Weight Matching (MWM) | ``MWM`` (uses chain access + stop) |
| Maximal Independent Set / Coloring| ``MIS`` (Luby-style, priority input) |
| Strongly Connected Components     | ``SCC`` (fwd/bwd label propagation) |
| Triangle Counting (TC)            | not DSL-expressible without list-valued
|                                   | messages (needs the FFI the paper
|                                   | mentions); provided as a substrate op in
|                                   | ``repro.graph`` instead — see DESIGN.md |
| Bi-Connected Components (BCC)     | needs vertex addition — unsupported in
|                                   | Palgol (paper §5.2), as in the paper |

Required initial fields are documented per program (e.g. ``P`` random
priorities for MIS, ``Side`` for bipartite matching).
"""

SSSP = """
# Single-source shortest path from vertex 0 (paper Fig. 4).
for v in V
    local D[v] := (Id[v] == 0 ? 0.0 : inf)
    local A[v] := (Id[v] == 0)
end
do
    for v in V
        let minDist = minimum [D[e.id] + e.w | e <- In[v], A[e.id]]
        local A[v] := false
        if (minDist < D[v])
            local A[v] := true
            local D[v] := minDist
    end
until fix [D]
"""

SV = """
# Shiloach-Vishkin connectivity (paper Fig. 6, verbatim semantics).
for u in V
    local D[u] := Id[u]
end
do
    for u in V
        if (D[D[u]] == D[u])
            let t = minimum [D[e.id] | e <- Nbr[u]]
            if (t < D[u])
                remote D[D[u]] <?= t
        else
            local D[u] := D[D[u]]
    end
until fix [D]
"""

PAGERANK = """
# PageRank, 30 rounds, damping 0.85; dangling mass dropped.
for v in V
    local Deg[v] := count [1 | e <- Out[v]]
    local PR[v] := 1.0 / numV
end
do
    for v in V
        let s = sum [PR[e.id] / Deg[e.id] | e <- In[v], Deg[e.id] > 0]
        local PR[v] := 0.15 / numV + 0.85 * s
    end
until iter [30]
"""

WCC = """
# Weakly connected components via HashMin label propagation.
for v in V
    local C[v] := Id[v]
end
do
    for v in V
        let m = minimum [C[e.id] | e <- Nbr[v]]
        if (m < C[v])
            local C[v] := m
    end
until fix [C]
"""

MIS = """
# Luby-style maximal independent set. Input field: P (random priorities).
for v in V
    local InMIS[v] := false
    local Done[v] := false
end
do
    for v in V
        if (!Done[v])
            let better = or [true | e <- Nbr[v], !Done[e.id] && ((P[e.id] < P[v]) || (P[e.id] == P[v] && Id[e.id] < Id[v]))]
            if (!better)
                local InMIS[v] := true
                local Done[v] := true
    end
    for v in V
        if (!Done[v])
            let nbrIn = or [InMIS[e.id] | e <- Nbr[v]]
            if (nbrIn)
                local Done[v] := true
    end
until fix [Done]
"""

BIPARTITE_MATCHING = """
# Bipartite matching, priority-deterministic variant of the paper's BM.
# Input field: Side (0 = left, 1 = right). M == numV means unmatched.
for v in V
    local M[v] := numV
    local Req[v] := numV
end
do
    for v in V
        if (Side[v] == 0 && M[v] == numV)
            let target = minimum [e.id | e <- Nbr[v], M[e.id] == numV]
            if (target < numV)
                remote Req[target] <?= Id[v]
    end
    for v in V
        if (Side[v] == 1 && M[v] == numV && Req[v] < numV)
            local M[v] := Req[v]
            remote M[Req[v]] <?= Id[v]
        local Req[v] := numV
    end
until fix [M]
"""

MWM = """
# Approximate maximum weight matching (Salihoglu-Widom MWM): point at the
# best unmatched neighbor; mutual pointers match. Uses a chain access
# (Cand[Cand[v]]) and vertex inactivation for matched pairs.
for v in V
    local M[v] := numV
    local Cand[v] := numV
end
do
    for v in V
        if (M[v] == numV)
            local Cand[v] := argmax [e.w | e <- Nbr[v], M[e.id] == numV]
    end
    for v in V
        if (M[v] == numV && Cand[v] < numV)
            if (Cand[Cand[v]] == Id[v])
                local M[v] := Cand[v]
    end
    stop v in V if M[v] < numV
until fix [M]
"""

SCC = """
# Strongly connected components via forward-backward label propagation:
# color = (min forward-reachable id, min backward-reachable id); vertices
# agreeing on both labels with a pivot form one SCC per round (simplified
# label-propagation SCC; full Yan et al. SCC adds graph shrinking).
for v in V
    local F[v] := Id[v]
    local B[v] := Id[v]
end
do
    for v in V
        let mf = minimum [F[e.id] | e <- In[v]]
        if (mf < F[v])
            local F[v] := mf
    end
until fix [F]
do
    for v in V
        if (F[v] == Id[v])
            local B[v] := Id[v]
        else
            let mb = minimum [B[e.id] | e <- Out[v], F[e.id] == F[v]]
            if (mb < B[v])
                local B[v] := mb
    end
until fix [B]
for v in V
    local SCCid[v] := (B[v] == F[v] ? F[v] : numV + Id[v])
end
"""

# the chain-access stress program from paper §4.1.1 (D⁴[u] in 3 rounds)
CHAIN4 = """
for u in V
    local D4[u] := D[D[D[D[u]]]]
end
"""

BFS = """
# BFS level from vertex 0 (unweighted shortest hop count).
for v in V
    local L[v] := (Id[v] == 0 ? 0 : inf)
end
do
    for v in V
        let m = minimum [L[e.id] + 1.0 | e <- In[v]]
        if (m < L[v])
            local L[v] := m
    end
until fix [L]
"""

KCORE = """
# k-core decomposition (peeling): iteratively drop vertices with active
# degree < k; Alive marks the k-core membership. Input field: K (constant).
for v in V
    local Alive[v] := true
end
do
    for v in V
        if (Alive[v])
            let deg = count [1 | e <- Nbr[v], Alive[e.id]]
            if (deg < K[v])
                local Alive[v] := false
    end
until fix [Alive]
"""

LABEL_PROP = """
# Label propagation communities (synchronous min-label compromise: adopt
# the smallest label among self and neighbors weighted by none — a
# deterministic LPA variant that converges under fix).
for v in V
    local C[v] := Id[v]
end
do
    for v in V
        let best = minimum [C[e.id] | e <- Nbr[v]]
        if (best < C[v])
            local C[v] := best
    end
until fix [C]
"""

ALL = {
    "sssp": SSSP,
    "sv": SV,
    "pagerank": PAGERANK,
    "wcc": WCC,
    "mis": MIS,
    "bipartite_matching": BIPARTITE_MATCHING,
    "mwm": MWM,
    "scc": SCC,
    "chain4": CHAIN4,
    "bfs": BFS,
    "kcore": KCORE,
    "label_prop": LABEL_PROP,
}
