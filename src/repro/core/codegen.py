"""Dense (TPU-native) code generation for Palgol steps.

Every Palgol step becomes a pure function ``(fields, graph) -> fields`` over
struct-of-arrays vertex state:

* all *reads* target the step's input fields (the paper's LC-phase rule:
  reads see the input graph);
* *local writes* read-modify-write an intermediate copy in program order;
* *remote writes* are collected during traversal and applied at the end via
  ``scatter_combine`` (the RU phase) — accumulative-only, so application
  order is irrelevant, exactly the paper's safety argument;
* chain accesses are evaluated through the :class:`~repro.core.logic.PullSolver`
  gather DAG (memoized per step ⇒ each distinct sub-chain evaluated once);
* halted vertices (paper §3.4) are immutable: their local writes are masked
  and remote writes to/from them are dropped.

The emitted functions contain no data-dependent Python control flow, so a
whole program (including fixed-point iterations as ``lax.while_loop``) traces
into a single XLA computation — one compiled module per Palgol program, with
collectives inserted by GSPMD when fields are sharded.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import ast
from repro.core.analysis import CompileError, chain_pattern_of
from repro.core.logic import PullSolver
from repro.core.plan import (
    HALTED,
    IterInit,
    MainCompute,
    OpRef,
    ReadRound,
    RemoteUpdate,
    StepPlan,
    StopOp,
    lower_step,
)
from repro.graph import ops as gops

# NOTE: the deprecated ``codegen.CHAIN_MODE`` module global (PR 3's
# one-release shim) is gone; the schedule is the explicit ``schedule=``
# argument on compile_program / StepExecutor / run_bsp.

_OP_APPLY = {
    ":=": lambda cur, val: val,
    "+=": lambda cur, val: cur + val,
    "*=": lambda cur, val: cur * val,
    "<?=": jnp.minimum,
    ">?=": jnp.maximum,
    "||=": jnp.logical_or,
    "&&=": jnp.logical_and,
}

_REDUCE_TO_COMBINER = {
    "minimum": "min",
    "maximum": "max",
    "sum": "sum",
    "prod": "prod",
    "and": "and",
    "or": "or",
}


@dataclasses.dataclass
class _EdgeCtx:
    direction: str
    nbr: jax.Array  # i32[E] neighbor ids (e.id) — global, value semantics
    vid: jax.Array  # i32[E] current-vertex id per edge — global, value sem.
    w: jax.Array  # f32[E] e.w
    emask: jax.Array  # bool[E]
    # addressing (== vid/nbr densely; local under a partitioned comm):
    seg: jax.Array = None  # row index of the current vertex (segment key)
    nbr_read: jax.Array = None  # address for reading per-row arrays at e.id

    def __post_init__(self):
        if self.seg is None:
            self.seg = self.vid
        if self.nbr_read is None:
            self.nbr_read = self.nbr


@dataclasses.dataclass
class _RemoteMsg:
    field: str
    op: str
    idx: jax.Array
    values: jax.Array
    mask: jax.Array  # same shape as idx


@dataclasses.dataclass
class _StepState:
    """One step's cross-superstep context under the fused program plan:
    what the step's remote-reading supersteps materialized and its main
    superstep emitted, threaded between the supersteps its plan ops landed
    in (the typed view of the executors' string-keyed mailbox)."""

    chain: Dict[tuple, jax.Array] = dataclasses.field(default_factory=dict)
    nbr: Dict[tuple, jax.Array] = dataclasses.field(default_factory=dict)
    pending: List[_RemoteMsg] = dataclasses.field(default_factory=list)
    naive_req: Dict[tuple, jax.Array] = dataclasses.field(default_factory=dict)


class StepExecutor:
    """Executes one Palgol step densely by folding its :class:`StepPlan`
    op list into one traced computation. Instantiated fresh per call so the
    expression memo-cache is scoped to the step (paper's CSE guarantee).

    ``plan`` (or ``schedule``, which lowers one) selects the superstep
    expansion — the same :func:`repro.core.plan.lower_step` plan the staged
    and partitioned executors consume, so the three can never diverge.

    ``comm`` selects the placement. ``None`` (default) is the dense /
    replicated path: fields are ``[N]`` arrays, reads are plain gathers.
    A :class:`repro.graph.partition.executor.ShardComm` makes this the
    ``placement="partitioned"`` path: the executor then runs *inside* a
    shard_map over per-shard field blocks ``[v_max]``, chain-access gathers
    route through the halo layer's dynamic request/reply exchange, neighbor
    reads through the static halo exchange, and remote-write scatters
    through the combiner-aware reduce-scatter. Vertex *values* (ids) stay
    global in both placements; only addressing changes.
    """

    def __init__(
        self,
        step: ast.Step,
        graph,
        comm=None,
        plan: Optional[StepPlan] = None,
        schedule: Optional[str] = None,
    ):
        self.step = step
        self.graph = graph
        self.comm = comm
        self.n = graph.n_vertices
        self.nrows = comm.n_rows if comm is not None else graph.n_vertices
        if plan is None:
            plan = lower_step(step, schedule=schedule or "pull")
        self.plan = plan
        self.info = plan.info
        self.pull = PullSolver()

    # -- public -------------------------------------------------------------
    def __call__(
        self,
        fields: Dict[str, jax.Array],
        chain_values: Optional[Dict[tuple, jax.Array]] = None,
        split_remote: bool = False,
        nbr_values: Optional[Dict[tuple, jax.Array]] = None,
    ):
        """Execute the plan's ops in order (fused into this one trace).

        ``chain_values`` seeds the chain cache with buffers materialized by
        earlier remote-reading supersteps (BSP mode) — seeded ReadRound
        work is skipped; ``nbr_values`` seeds per-edge neighborhood buffers
        keyed by ``(direction, pattern)``. In dense mode the rounds inline
        their gathers here instead.
        With ``split_remote=True`` returns ``(fields, pending_messages)`` so
        a separate remote-updating superstep can apply them (paper Fig. 9).
        """
        self.old = dict(fields)
        self.new = dict(fields)
        self.env: Dict[str, Tuple[str, jax.Array]] = {}
        self.chain_cache: Dict[tuple, jax.Array] = dict(chain_values or {})
        self.nbr_cache: Dict[tuple, jax.Array] = dict(nbr_values or {})
        self.expr_cache: Dict[Tuple[int, ast.Expr], jax.Array] = {}
        self.pending: List[_RemoteMsg] = []
        self._naive_req: Dict[tuple, jax.Array] = {}
        self.active = self._active_mask(fields)
        for op in self.plan.ops:
            if isinstance(op, ReadRound):
                self._exec_read_round(op)
            elif isinstance(op, MainCompute):
                self._exec_stmts(self.step.body, mask=None, ectx=None)
            elif not split_remote:  # RemoteUpdate
                self._apply_remote()
        if split_remote:
            return self.new, self.pending
        return self.new

    def apply_remote(self, fields, pending: List[_RemoteMsg]):
        """RU phase as a standalone superstep (BSP mode)."""
        self.old = dict(fields)
        self.new = dict(fields)
        self.pending = pending
        self.active = self._active_mask(fields)
        self._apply_remote()
        return self.new

    def run_ops(self, fields, ops, state: Optional["_StepState"] = None):
        """Execute a slice of this step's plan ops — the per-superstep entry
        point of the fused program plan (``repro.core.plan.ProgramPlan``),
        where one fused superstep may hold ops from several steps and a
        step's ops may land in different supersteps.

        ``state`` threads the step's cross-superstep context (materialized
        chain/neighborhood buffers, pending remote messages, naive request
        buffers) between slices; results are identical to one ``__call__``
        over the whole plan because ReadRounds never write fields — each
        slice re-snapshotting ``fields`` sees exactly the state the unfused
        superstep at that position would.
        """
        state = state if state is not None else _StepState()
        self.old = dict(fields)
        self.new = dict(fields)
        self.env = {}
        self.chain_cache = dict(state.chain)
        self.nbr_cache = dict(state.nbr)
        self.expr_cache = {}
        self.pending = list(state.pending)
        self._naive_req = dict(state.naive_req)
        self.active = self._active_mask(fields)
        for op in ops:
            if isinstance(op, ReadRound):
                self._exec_read_round(op)
            elif isinstance(op, MainCompute):
                self._exec_stmts(self.step.body, mask=None, ectx=None)
            else:  # RemoteUpdate
                self._apply_remote()
                self.pending = []
        out_state = _StepState(
            # axioms (vertex ids / single-field reads) must not outlive the
            # superstep — a carried copy would go stale once the field is
            # written; only materialized multi-hop buffers are the mailbox
            chain={p: v for p, v in self.chain_cache.items() if len(p) > 1},
            nbr=dict(self.nbr_cache),
            pending=list(self.pending),
            naive_req=dict(self._naive_req),
        )
        return self.new, out_state

    # -- helpers ------------------------------------------------------------
    def _active_mask(self, fields) -> jax.Array:
        active = ~fields.get(HALTED, jnp.zeros((self.nrows,), jnp.bool_))
        if self.comm is not None:  # padding rows of a shard are never active
            active = jnp.logical_and(active, self.comm.valid)
        return active

    def _ids(self) -> jax.Array:
        if self.comm is not None:
            return self.comm.ids()
        return jnp.arange(self.n, dtype=jnp.int32)

    def _gather_rows(self, arr: jax.Array, idx: jax.Array, fill=None):
        """Read a per-row array at *global* vertex ids (possibly remote)."""
        if self.comm is not None:
            return self.comm.gather(arr, idx, fill)
        return gops.gather(arr, idx, fill)

    def _read_nbr(self, per_row: jax.Array, ectx: _EdgeCtx) -> jax.Array:
        """Read a per-row array at each edge's neighbor (static halo path)."""
        if self.comm is not None:
            return self.comm.read_edge(per_row, ectx)
        return gops.gather(per_row, ectx.nbr_read)

    def _edge_ctx(self, direction: str) -> _EdgeCtx:
        if self.comm is not None:
            return self.comm.edge_ctx(direction)
        nbr, vid, w, m = self.graph.edges(direction)
        return _EdgeCtx(direction, nbr, vid, w, m)

    def _field(self, name: str) -> jax.Array:
        if name == "Id":
            return self._ids()
        if name not in self.old:
            raise CompileError(f"read of undefined field {name!r}")
        return self.old[name]

    def _chain_value(self, pattern: tuple) -> jax.Array:
        """Evaluate a chain pattern at every vertex. The plan's ReadRound
        ops materialize every multi-hop pattern before the main compute, so
        during statement execution this resolves axioms (vertex ids, single
        fields) and cache hits; the pull-DAG fallback covers synthetic
        steps that run without plan rounds (stop conditions)."""
        if pattern in self.chain_cache:
            return self.chain_cache[pattern]
        if len(pattern) == 0:
            val = self._ids()
        elif len(pattern) == 1:
            val = self._field(pattern[0])
        else:
            # pull-mode pointer doubling: under a partitioned comm each
            # doubling round is a dynamic cross-shard gather whose request
            # set is rebuilt from the current indirection values
            plan = self.pull.solve(pattern)
            pre = self._chain_value(plan.prefix.pattern)
            suf = self._chain_value(plan.suffix.pattern)
            val = self._gather_rows(suf, pre)
        self.chain_cache[pattern] = val
        return val

    # -- plan-op execution ---------------------------------------------------
    def _exec_read_round(self, op: ReadRound):
        """Fold one remote-reading superstep into the trace.

        Work whose result is already cached (seeded by a staged mailbox)
        is skipped — the op then only accounts for its superstep.
        """
        if op.kind == "request":
            # naive hop, requester→owner address push. Under a partitioned
            # comm the paired reply's gather_global pays the request
            # exchange for real; densely we keep the address scatter alive
            # so the lowered HLO carries the wire traffic manual code pays.
            if self.comm is not None:
                return
            for ce in op.chains:
                if ce.pattern in self.chain_cache:
                    continue
                cur = self._chain_value(ce.prefix)
                req = jnp.full((self.n + 1,), self.n, jnp.int32)
                self._naive_req[ce.pattern] = req.at[cur].set(
                    self._ids(), mode="drop"
                )[: self.n]
            return
        if op.kind == "push_request":
            # push address-propagation round: requester ids are forwarded
            # (combined per owner) along the chain. The fused dense trace
            # has no wire, so this op only accounts for its superstep;
            # under a partitioned comm the push_reply round's
            # gather_global pays the combined exchange for real.
            return
        # kind "pull", "reply" or "push_reply": gather suffix@prefix
        # (push_reply is the combined reply — one value per distinct
        # owner, fanned out to its requesters: exactly the gather)
        for ce in op.chains:
            if ce.pattern in self.chain_cache:
                continue
            pre = self._chain_value(ce.prefix)
            suf = self._chain_value(ce.suffix)
            val = self._gather_rows(suf, pre)
            req = self._naive_req.pop(ce.pattern, None)
            if req is not None:
                # fold in the request buffer: req < n+2 always, so this
                # term is exactly zero, but the algebraic simplifier can't
                # prove it — the scatter survives into the lowering
                val = val + (req // (self.n + 2)).astype(val.dtype)
            self.chain_cache[ce.pattern] = val
        for direction, npat in op.nbr_sends:
            if (direction, npat) in self.nbr_cache:
                continue
            per_vertex = self._chain_value(npat)
            ectx = self._edge_ctx(direction)
            self.nbr_cache[(direction, npat)] = self._read_nbr(per_vertex, ectx)

    # -- expression evaluation ----------------------------------------------
    def _eval(self, e: ast.Expr, ectx: Optional[_EdgeCtx]):
        key = (id(ectx), e)
        if key in self.expr_cache:
            return self.expr_cache[key]
        val = self._eval_inner(e, ectx)
        self.expr_cache[key] = val
        return val

    def _eval_inner(self, e: ast.Expr, ectx: Optional[_EdgeCtx]):
        if isinstance(e, ast.Const):
            if e.value == "inf":
                return jnp.inf
            return e.value
        if isinstance(e, ast.Var):
            if e.name == "numV":  # builtin: vertex count (global constant)
                return jnp.asarray(self.n, jnp.int32)
            if e.name == self.step.vertex_var:
                return ectx.vid if ectx is not None else self._ids()
            if e.name in self.env:
                ctx_tag, arr = self.env[e.name]
                if ctx_tag == "vertex" and ectx is not None:
                    return gops.gather(arr, ectx.seg)
                return arr
            raise CompileError(f"unbound variable {e.name!r}")
        if isinstance(e, ast.EdgeProp):
            if ectx is None:
                raise CompileError(f".{e.prop} outside edge context")
            return ectx.nbr if e.prop == "id" else ectx.w
        if isinstance(e, ast.FieldAccess):
            # chain access from current vertex
            pat = chain_pattern_of(e, self.step.vertex_var)
            if pat is not None:
                val = self._chain_value(pat)
                return gops.gather(val, ectx.seg) if ectx is not None else val
            # neighborhood chain from e.id
            if ectx is not None:
                npat = self._nbr_pattern(e)
                if npat is not None:
                    cached = self.nbr_cache.get((ectx.direction, npat))
                    if cached is not None:
                        return cached
                    per_vertex = self._chain_value(npat)
                    return self._read_nbr(per_vertex, ectx)
            # general read
            idx = self._eval(e.index, ectx)
            return self._gather_rows(
                self._field(e.field), jnp.asarray(idx, jnp.int32)
            )
        if isinstance(e, ast.Cond):
            c = self._eval(e.cond, ectx)
            t = self._eval(e.then, ectx)
            f = self._eval(e.other, ectx)
            return jnp.where(c, t, f)
        if isinstance(e, ast.BinOp):
            lhs = self._eval(e.left, ectx)
            rhs = self._eval(e.right, ectx)
            return _binop(e.op, lhs, rhs)
        if isinstance(e, ast.UnOp):
            x = self._eval(e.operand, ectx)
            return jnp.logical_not(x) if e.op == "!" else -x
        if isinstance(e, ast.Reduce):
            return self._eval_reduce(e)
        raise CompileError(f"cannot evaluate {type(e).__name__}")

    def _nbr_pattern(self, e: ast.FieldAccess):
        # pattern starting from any edge var's `.id` — edge var name is the
        # enclosing loop's; analysis validated scoping, so accept any
        def rec(x):
            if isinstance(x, ast.EdgeProp) and x.prop == "id":
                return ()
            if isinstance(x, ast.FieldAccess):
                inner = rec(x.index)
                if inner is not None:
                    return inner + (x.field,)
            return None

        return rec(e)

    def _eval_reduce(self, e: ast.Reduce) -> jax.Array:
        ectx = self._edge_ctx(e.range.direction)
        mask = ectx.emask
        for f in e.filters:
            fv = self._eval(f, ectx)
            mask = jnp.logical_and(mask, fv)
        if e.func == "count":
            ones = jnp.ones_like(ectx.seg, dtype=jnp.int32)
            return gops.segment_reduce(
                ones, ectx.seg, self.nrows, "sum",
                indices_are_sorted=True, mask=mask,
            )
        body = self._eval(e.body, ectx)
        body = jnp.asarray(body)
        if body.ndim == 0:
            body = jnp.broadcast_to(body, ectx.seg.shape)
        if e.func in ("argmin", "argmax"):
            comb = "min" if e.func == "argmin" else "max"
            best = gops.segment_reduce(
                body, ectx.seg, self.nrows, comb,
                indices_are_sorted=True, mask=mask,
            )
            attained = jnp.logical_and(mask, body == gops.gather(best, ectx.seg))
            ids = jnp.where(attained, ectx.nbr, self.n)
            out = gops.segment_reduce(
                ids, ectx.seg, self.nrows, "min", indices_are_sorted=True
            )
            # empty segments reduce to int-max; clamp to the sentinel (numV)
            return jnp.minimum(out, self.n)
        comb = _REDUCE_TO_COMBINER[e.func]
        return gops.segment_reduce(
            body, ectx.seg, self.nrows, comb, indices_are_sorted=True, mask=mask
        )

    # -- statement execution -------------------------------------------------
    def _exec_stmts(self, stmts, mask, ectx: Optional[_EdgeCtx]):
        for s in stmts:
            if isinstance(s, ast.Let):
                val = self._eval(s.value, ectx)
                val = jnp.asarray(val)
                tag = "edge" if ectx is not None else "vertex"
                if val.ndim == 0:
                    shape = ectx.seg.shape if ectx is not None else (self.nrows,)
                    val = jnp.broadcast_to(val, shape)
                self.env[s.var] = (tag, val)
            elif isinstance(s, ast.If):
                c = self._eval(s.cond, ectx)
                c = jnp.asarray(c)
                if c.ndim == 0:
                    shape = ectx.seg.shape if ectx is not None else (self.nrows,)
                    c = jnp.broadcast_to(c, shape)
                m_then = c if mask is None else jnp.logical_and(mask, c)
                self._exec_stmts(s.then, m_then, ectx)
                if s.other:
                    m_else = ~c if mask is None else jnp.logical_and(mask, ~c)
                    self._exec_stmts(s.other, m_else, ectx)
            elif isinstance(s, ast.ForEdges):
                ec = self._edge_ctx(s.range.direction)
                m = ec.emask
                if mask is not None:  # lift vertex mask to edges
                    m = jnp.logical_and(m, gops.gather(mask, ec.seg, fill=False))
                self._exec_stmts(s.body, m, ec)
            elif isinstance(s, ast.LocalWrite):
                self._local_write(s, mask, ectx)
            elif isinstance(s, ast.RemoteWrite):
                self._remote_write(s, mask, ectx)
            else:
                raise CompileError(f"unknown statement {type(s).__name__}")

    def _local_write(self, s: ast.LocalWrite, mask, ectx: Optional[_EdgeCtx]):
        val = jnp.asarray(self._eval(s.value, ectx))
        if ectx is None:
            if val.ndim == 0:
                val = jnp.broadcast_to(val, (self.nrows,))
            cur = self.new.get(s.field)
            if cur is None:
                if s.op != ":=":
                    raise CompileError(
                        f"field {s.field!r} first written with accumulative op"
                    )
                cur = jnp.zeros((self.nrows,), val.dtype)
            updated = _OP_APPLY[s.op](cur, val).astype(cur.dtype)
            m = self.active if mask is None else jnp.logical_and(mask, self.active)
            self.new[s.field] = jnp.where(m, updated, cur)
        else:
            # accumulative write inside an edge loop: segment-reduce per-edge
            # contributions, then fold into the intermediate field once.
            if s.op == ":=":
                raise CompileError("`:=` inside an edge loop is order-dependent")
            comb = ast.OP_TO_COMBINER[s.op]
            if val.ndim == 0:
                val = jnp.broadcast_to(val, ectx.seg.shape)
            m = ectx.emask if mask is None else mask
            cur = self.new.get(s.field)
            if cur is None:
                raise CompileError(
                    f"field {s.field!r} must exist before accumulation in a loop"
                )
            seg = gops.segment_reduce(
                val.astype(cur.dtype), ectx.seg, self.nrows, comb,
                indices_are_sorted=True, mask=m,
            )
            updated = _OP_APPLY[s.op](cur, seg).astype(cur.dtype)
            self.new[s.field] = jnp.where(self.active, updated, cur)

    def _remote_write(self, s: ast.RemoteWrite, mask, ectx: Optional[_EdgeCtx]):
        idx = jnp.asarray(self._eval(s.target, ectx), jnp.int32)
        val = jnp.asarray(self._eval(s.value, ectx))
        shape = ectx.seg.shape if ectx is not None else (self.nrows,)
        if idx.ndim == 0:
            idx = jnp.broadcast_to(idx, shape)
        if val.ndim == 0:
            val = jnp.broadcast_to(val, shape)
        # sender must be active
        sender_active = (
            gops.gather(self.active, ectx.seg, fill=False)
            if ectx is not None
            else self.active
        )
        m = sender_active if mask is None else jnp.logical_and(mask, sender_active)
        if ectx is not None:
            m = jnp.logical_and(m, ectx.emask)
        self.pending.append(_RemoteMsg(s.field, s.op, idx, val, m))

    def _apply_remote(self):
        for msg in self.pending:
            if msg.field not in self.new:
                raise CompileError(
                    f"remote write to undefined field {msg.field!r}"
                )
            buf = self.new[msg.field]
            comb = ast.OP_TO_COMBINER[msg.op]
            if self.comm is not None:
                # route the scatter through the halo layer's reduce-scatter:
                # senders pre-combine locally, owners fold the delta in.
                # Receiver-activity masking is local to the owner — halted
                # receivers drop the whole combined delta, matching the
                # dense per-message drop (all messages to a halted vertex
                # are dropped together).
                delta = self.comm.scatter_reduce(
                    msg.idx, msg.values.astype(buf.dtype), comb, msg.mask
                )
                combined = _fold_combiner(comb, buf, delta)
                mshape = self.active.shape + (1,) * (buf.ndim - 1)
                self.new[msg.field] = jnp.where(
                    self.active.reshape(mshape), combined, buf
                )
                continue
            # receiver must be active
            recv_active = gops.gather(self.active, msg.idx, fill=False)
            m = jnp.logical_and(msg.mask, recv_active)
            self.new[msg.field] = gops.scatter_combine(
                buf, msg.idx, msg.values.astype(buf.dtype), comb, mask=m
            )


def _fold_combiner(op: str, cur: jax.Array, delta: jax.Array) -> jax.Array:
    """Fold a pre-combined remote-write delta into the live field.

    ``delta`` is identity-valued where no message arrived, so the fold is a
    no-op there — the partitioned equivalent of scatter's "unreduced rows
    keep their value"."""
    return gops.combine(op, cur, delta).astype(cur.dtype)


def _binop(op: str, lhs, rhs):
    if op == "+":
        return lhs + rhs
    if op == "-":
        return lhs - rhs
    if op == "*":
        return lhs * rhs
    if op == "/":
        # float division unless both ints and exact context; Palgol `/` is
        # numeric division (PageRank), use true division then keep dtype rules
        return jnp.asarray(lhs) / rhs
    if op == "%":
        return jnp.asarray(lhs) % rhs
    if op == "==":
        return jnp.equal(lhs, rhs)
    if op == "!=":
        return jnp.not_equal(lhs, rhs)
    if op == "<":
        return jnp.less(lhs, rhs)
    if op == "<=":
        return jnp.less_equal(lhs, rhs)
    if op == ">":
        return jnp.greater(lhs, rhs)
    if op == ">=":
        return jnp.greater_equal(lhs, rhs)
    if op == "&&":
        return jnp.logical_and(lhs, rhs)
    if op == "||":
        return jnp.logical_or(lhs, rhs)
    raise CompileError(f"unknown operator {op!r}")


# ---------------------------------------------------------------------------
# fused-program-plan execution: one Superstep part at a time
#
# The program-level mailbox is a flat string-keyed dict so every consumer
# (the fused dense trace, the partitioned shard_map body) can thread it as
# one pytree. Keys are namespaced by step ordinal (``s<i>:``) so two steps
# materializing the same chain pattern cannot collide:
#
#   s<i>:chain:<f1>/<f2>...   materialized chain buffer (pattern-keyed)
#   s<i>:nbr:<dir>:<f1>...    per-edge neighborhood buffer
#   s<i>:req:<f1>/...         naive request buffer (dense wire emulation)
#   s<i>:pending              remote-write payload (Main -> RemoteUpdate),
#                             a tuple of (idx, values, mask) triples in
#                             RemoteUpdate.writes order


def _pat_key(pattern: tuple) -> str:
    return "/".join(pattern)


def _ns_import(ns: str, mailbox, ru_writes) -> "_StepState":
    """Decode one step's mailbox entries into its typed _StepState."""
    state = _StepState()
    for k, v in mailbox.items():
        if not k.startswith(ns):
            continue
        rest = k[len(ns):]
        if rest.startswith("chain:"):
            state.chain[tuple(rest[len("chain:"):].split("/"))] = v
        elif rest.startswith("nbr:"):
            _, direction, pat = rest.split(":", 2)
            state.nbr[(direction, tuple(pat.split("/")) if pat else ())] = v
        elif rest.startswith("req:"):
            state.naive_req[tuple(rest[len("req:"):].split("/"))] = v
        elif rest == "pending":
            state.pending = [
                _RemoteMsg(f, op, idx, val, mask)
                for (f, op), (idx, val, mask) in zip(ru_writes, v)
            ]
    return state


def _ns_export(ns: str, mailbox, op, state: "_StepState"):
    """Re-encode a step's post-op state into the mailbox.

    The drop policy keeps loop-carried mailbox keysets stable (a fixed
    while-carry structure for the fused dense trace, one retrace per
    superstep for the dispatching executors): MainCompute consumes the
    step's read buffers, RemoteUpdate consumes its pending payload — after
    a step's last op only prefetched entries (re-created by the fused
    loop's trailing ReadRound) remain.
    """
    out = {k: v for k, v in mailbox.items() if not k.startswith(ns)}
    pending = tuple((m.idx, m.values, m.mask) for m in state.pending)
    if isinstance(op, ReadRound):
        for p, v in state.chain.items():
            out[f"{ns}chain:{_pat_key(p)}"] = v
        for (d, p), v in state.nbr.items():
            out[f"{ns}nbr:{d}:{_pat_key(p)}"] = v
        for p, v in state.naive_req.items():
            out[f"{ns}req:{_pat_key(p)}"] = v
        if pending:
            out[f"{ns}pending"] = pending
    elif isinstance(op, MainCompute):
        if pending:
            out[f"{ns}pending"] = pending
    # RemoteUpdate: everything consumed
    return out


def exec_plan_part(ref: OpRef, graph, comm, fields, mailbox):
    """Execute one part of a fused :class:`~repro.core.plan.Superstep`.

    The shared per-op consumer of the program plan: the fused dense
    compiler folds these calls into its single trace (``comm=None``) and
    the partitioned executor runs them inside its per-superstep shard_map
    body (``comm=ShardComm``). Returns ``(fields, mailbox)``.
    """
    op = ref.op
    if isinstance(op, IterInit):
        return fields, mailbox
    if isinstance(op, StopOp):
        return make_stop_fn(op.stop, graph, comm=comm)(fields), mailbox
    ns = f"s{ref.sidx}:"
    plan = ref.plan
    ru = next((o for o in plan.ops if isinstance(o, RemoteUpdate)), None)
    state = _ns_import(ns, mailbox, ru.writes if ru is not None else ())
    ex = StepExecutor(plan.step, graph, comm=comm, plan=plan)
    fields, state = ex.run_ops(fields, [op], state)
    return fields, _ns_export(ns, mailbox, op, state)


def make_stop_fn(stop: ast.StopStep, graph, comm=None):
    """StopStep → fields update flipping the halted mask (paper §3.4)."""

    def stop_fn(fields):
        # reuse StepExecutor's evaluator on a synthetic empty step
        ex = StepExecutor(ast.Step(stop.vertex_var, ()), graph, comm=comm)
        ex.old = dict(fields)
        ex.new = dict(fields)
        ex.env = {}
        ex.chain_cache = {}
        ex.nbr_cache = {}
        ex.expr_cache = {}
        ex.pending = []
        ex.active = ex._active_mask(fields)
        cond = jnp.asarray(ex._eval(stop.cond, None))
        if cond.ndim == 0:
            cond = jnp.broadcast_to(cond, (ex.nrows,))
        halted = fields.get(HALTED, jnp.zeros((ex.nrows,), jnp.bool_))
        out = dict(fields)
        out[HALTED] = jnp.logical_or(halted, cond)
        return out

    return stop_fn
