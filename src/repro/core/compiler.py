"""Palgol program compilation: AST → executable JAX + STM cost models.

``compile_program`` produces a :class:`CompiledProgram` whose ``fn`` is a
pure, jit-able ``fields → (fields, trips)`` function: fixed-point iterations
become ``lax.while_loop`` (termination via a global any-changed reduction —
Pregel's OR aggregator), sequences compose, and the whole Palgol program
traces into a single XLA computation. ``trips`` counts body executions per
iteration node so the STM cost models can report superstep totals for the
paper's Table-5 accounting.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import ast
from repro.core import parser as palgol_parser
from repro.core import plan as plan_mod
from repro.core import stm as stm_mod
from repro.core.analysis import CompileError, iter_steps
from repro.core.codegen import HALTED, StepExecutor, exec_plan_part, make_stop_fn
from repro.core.plan import ByteCostModel, SCHEDULES, lower_step

# pre-order Iter list — the shared iteration-counter index order
_iter_nodes = plan_mod.iter_nodes


@dataclasses.dataclass
class CompiledProgram:
    prog: ast.Prog
    graph: object
    field_struct: Dict[str, jax.ShapeDtypeStruct]
    n_iters: int
    max_iters: int
    cost_models: Dict[str, stm_mod.CostModel]
    # chain-access schedule the fused trace lowers under ("pull" | "push" |
    # "naive" | "auto"); None means "pull"
    schedule: Optional[str] = None
    # per-round byte estimates feeding the byte-aware ``auto`` selector
    # (None: auto selects on op count alone)
    byte_costs: Optional[ByteCostModel] = None
    # apply the §4.3 fuse pass (state merging + iteration fusion) to the
    # program plan ``fn`` folds into its trace; False keeps the unfused
    # per-op expansion for A/B comparisons
    fuse: bool = True

    def step_plans(
        self, schedule: Optional[str] = None
    ) -> List[tuple]:
        """``(step, StepPlan)`` for every Step node, in program order —
        what ``fn`` folds into the trace (dry-run / benchmark surface)."""
        sched = (
            schedule if schedule is not None else self.schedule
        ) or "pull"
        return [
            (s, lower_step(s, schedule=sched, byte_costs=self.byte_costs))
            for s in iter_steps(self.prog)
            if isinstance(s, ast.Step)
        ]

    def program_plan(
        self,
        schedule: Optional[str] = None,
        fuse: Optional[bool] = None,
    ) -> plan_mod.ProgramPlan:
        """The whole-program superstep schedule ``fn`` executes — fused by
        default (§4.3 state merging + iteration fusion applied for real)."""
        sched = (
            schedule if schedule is not None else self.schedule
        ) or "pull"
        pp = plan_mod.lower_program(
            self.prog, schedule=sched, byte_costs=self.byte_costs
        )
        if self.fuse if fuse is None else fuse:
            pp = plan_mod.fuse(pp)
        return pp

    def init_fields(self, user_fields: Optional[Dict[str, jax.Array]] = None):
        """Canonical field dict: user fields + zero-init for created fields."""
        fields = {}
        user_fields = user_fields or {}
        for name, sds in self.field_struct.items():
            if name in user_fields:
                arr = jnp.asarray(user_fields[name])
                if arr.shape != sds.shape or arr.dtype != sds.dtype:
                    arr = jnp.broadcast_to(arr, sds.shape).astype(sds.dtype)
                fields[name] = arr
            else:
                fields[name] = jnp.zeros(sds.shape, sds.dtype)
        for name in user_fields:
            if name not in fields:
                fields[name] = jnp.asarray(user_fields[name])
        return fields

    def fn(self, fields: Dict[str, jax.Array], graph=None):
        """Pure program function: fields → (fields, trips[i32[n_iters]]).

        Folds the (by default fused) :class:`~repro.core.plan.ProgramPlan`
        into one trace: superstep parts execute in plan order against the
        program-level mailbox, and a fused loop's prefetched ReadRound
        buffers ride the ``lax.while_loop`` carry — the loop-back edge of
        §4.3.2 iteration fusion, traced for real.

        ``graph`` overrides the compile-time graph *data* (same static
        shape), making the graph a traced argument — required when lowering
        against a device mesh (closure arrays would bake in as constants).
        """
        graph = graph if graph is not None else self.graph
        pp = self.program_plan()
        trips0 = jnp.zeros((max(self.n_iters, 1),), jnp.int32)

        def run_items(items, flds, mailbox, trips):
            for it in items:
                if isinstance(it, plan_mod.Superstep):
                    for ref in it.parts:
                        flds, mailbox = exec_plan_part(
                            ref, graph, None, flds, mailbox
                        )
                    continue
                # PlanLoop: the mailbox joins the while carry — prefetched
                # chain/nbr buffers are re-created by the fused body's
                # trailing ReadRound, so the carry structure is stable
                fix = it.node.fix_fields
                limit = (
                    it.node.fixed_trips
                    if it.node.fixed_trips is not None
                    else self.max_iters
                )
                for name in fix:
                    if name not in flds:
                        raise CompileError(f"fix field {name!r} undefined")

                def cond(carry, _limit=limit):
                    _, _, _, changed, k = carry
                    return jnp.logical_and(changed, k < _limit)

                def body(carry, _it=it, _fix=fix):
                    f, m, t, _, k = carry
                    new_f, m, t = run_items(_it.body, f, m, t)
                    if _fix:
                        changed = jnp.asarray(False)
                        for name in _fix:
                            changed = jnp.logical_or(
                                changed, jnp.any(new_f[name] != f[name])
                            )
                    else:
                        changed = jnp.asarray(True)  # fixed-trip iteration
                    t = t.at[_it.iter_index].add(1)
                    return new_f, m, t, changed, k + 1

                carry = (
                    flds, mailbox, trips,
                    jnp.asarray(True), jnp.asarray(0, jnp.int32),
                )
                flds, mailbox, trips, _, _ = jax.lax.while_loop(
                    cond, body, carry
                )
            return flds, mailbox, trips

        out_fields, _, trips = run_items(pp.items, dict(fields), {}, trips0)
        return out_fields, trips

    def run(
        self,
        user_fields: Optional[Dict[str, jax.Array]] = None,
        jit: bool = True,
    ):
        """Execute; returns (fields, trips, superstep counts per regime)."""
        fields = self.init_fields(user_fields)
        fn = jax.jit(self.fn) if jit else self.fn
        out, trips = fn(fields)
        trips_host = [int(x) for x in trips]
        counts = {
            name: cm.count(trips_host) for name, cm in self.cost_models.items()
        }
        return out, trips_host, counts


def _discover_fields(prog, graph, fields_struct):
    """eval_shape pass discovering created fields + stable dtypes."""

    def step_pass(step, fs):
        def f(flds):
            # field discovery is schedule-independent (identical shapes /
            # dtypes under every schedule) — pin pull for determinism
            return StepExecutor(step, graph, schedule="pull")(flds)

        return dict(jax.eval_shape(f, fs))

    def stop_pass(stop, fs):
        def f(flds):
            return make_stop_fn(stop, graph)(flds)

        return dict(jax.eval_shape(f, fs))

    def go(p, fs):
        if isinstance(p, ast.Step):
            return step_pass(p, fs)
        if isinstance(p, ast.StopStep):
            return stop_pass(p, fs)
        if isinstance(p, ast.Seq):
            for q in p.progs:
                fs = go(q, fs)
            return fs
        if isinstance(p, ast.Iter):
            fs2 = go(p.body, fs)
            # one more pass with the enriched struct: dtypes must be stable
            fs3 = go(p.body, fs2)
            if {k: (v.shape, v.dtype) for k, v in fs2.items()} != {
                k: (v.shape, v.dtype) for k, v in fs3.items()
            }:
                raise CompileError(
                    "iteration body changes field shapes/dtypes between "
                    "iterations — not expressible as a fixed carry"
                )
            return fs2
        raise CompileError(f"unknown program node {type(p).__name__}")

    return go(prog, dict(fields_struct))


def compile_program(
    source_or_ast: Union[str, ast.Prog],
    graph,
    initial_fields: Optional[Dict[str, jax.Array]] = None,
    max_iters: int = 100_000,
    schedule: Optional[str] = None,
    byte_costs: Optional[ByteCostModel] = None,
    fuse: bool = True,
) -> CompiledProgram:
    """Compile Palgol source (or AST) against a graph.

    ``initial_fields`` supplies dtypes/values of pre-existing fields; fields
    created by the program (via ``local F[v] := ...``) are discovered with an
    abstract-evaluation pass and zero-initialized.

    ``schedule`` selects the chain-access lowering the fused trace folds
    in (``"pull"`` — pointer-doubling gather DAG, ``"push"`` — the
    paper-faithful request/combined-reply message schedule, ``"naive"`` —
    per-hop request/reply wire-cost model, ``"auto"`` — per-step cheapest).
    ``None`` means ``"pull"``. ``byte_costs`` (a
    :class:`repro.core.plan.ByteCostModel`, e.g. from
    :func:`repro.graph.partition.byte_cost_model`) makes ``"auto"`` select
    on (supersteps, modeled wire bytes) instead of op count; the STM
    ``auto`` cost model is built with the same costs so the accounting
    tracks the selection.

    ``fuse`` (default True) applies the §4.3 program-level optimizations
    (state merging + iteration fusion, :func:`repro.core.plan.fuse`) to the
    plan the trace folds in; ``fuse=False`` keeps the unfused per-op
    expansion for A/B comparisons. Results are bit-identical either way —
    fusion moves superstep boundaries, never reorders primitive ops.
    """
    prog = (
        palgol_parser.parse(source_or_ast)
        if isinstance(source_or_ast, str)
        else source_or_ast
    )
    if schedule is not None and schedule not in SCHEDULES:
        raise CompileError(
            f"unknown schedule {schedule!r}; expected one of {SCHEDULES}"
        )
    n = graph.n_vertices
    fs: Dict[str, jax.ShapeDtypeStruct] = {
        HALTED: jax.ShapeDtypeStruct((n,), jnp.bool_)
    }
    for name, arr in (initial_fields or {}).items():
        arr = jnp.asarray(arr)
        fs[name] = jax.ShapeDtypeStruct(arr.shape, arr.dtype)
    field_struct = _discover_fields(prog, graph, fs)
    cost_models = stm_mod.superstep_report(prog, byte_costs=byte_costs)
    return CompiledProgram(
        prog=prog,
        graph=graph,
        field_struct=field_struct,
        n_iters=len(_iter_nodes(prog)),
        max_iters=max_iters,
        cost_models=cost_models,
        schedule=schedule,
        byte_costs=byte_costs,
        fuse=fuse,
    )
