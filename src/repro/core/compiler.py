"""Palgol program compilation: AST → executable JAX + STM cost models.

``compile_program`` produces a :class:`CompiledProgram` whose ``fn`` is a
pure, jit-able ``fields → (fields, trips)`` function: fixed-point iterations
become ``lax.while_loop`` (termination via a global any-changed reduction —
Pregel's OR aggregator), sequences compose, and the whole Palgol program
traces into a single XLA computation. ``trips`` counts body executions per
iteration node so the STM cost models can report superstep totals for the
paper's Table-5 accounting.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp

from repro.core import ast
from repro.core import parser as palgol_parser
from repro.core import stm as stm_mod
from repro.core.analysis import CompileError, iter_steps
from repro.core.codegen import HALTED, StepExecutor, make_stop_fn
from repro.core.plan import ByteCostModel, SCHEDULES, StepPlan, lower_step


def _iter_nodes(prog: ast.Prog) -> List[ast.Iter]:
    """Pre-order list of Iter nodes — index order matches stm.build_stm."""
    out: List[ast.Iter] = []

    def go(p):
        if isinstance(p, ast.Seq):
            for q in p.progs:
                go(q)
        elif isinstance(p, ast.Iter):
            out.append(p)
            go(p.body)

    go(prog)
    return out


@dataclasses.dataclass
class CompiledProgram:
    prog: ast.Prog
    graph: object
    field_struct: Dict[str, jax.ShapeDtypeStruct]
    n_iters: int
    max_iters: int
    cost_models: Dict[str, stm_mod.CostModel]
    # chain-access schedule the fused trace lowers under ("pull" | "push" |
    # "naive" | "auto"); None means "pull"
    schedule: Optional[str] = None
    # per-round byte estimates feeding the byte-aware ``auto`` selector
    # (None: auto selects on op count alone)
    byte_costs: Optional[ByteCostModel] = None

    def step_plans(
        self, schedule: Optional[str] = None
    ) -> List[tuple]:
        """``(step, StepPlan)`` for every Step node, in program order —
        what ``fn`` folds into the trace (dry-run / benchmark surface)."""
        sched = (
            schedule if schedule is not None else self.schedule
        ) or "pull"
        return [
            (s, lower_step(s, schedule=sched, byte_costs=self.byte_costs))
            for s in iter_steps(self.prog)
            if isinstance(s, ast.Step)
        ]

    def init_fields(self, user_fields: Optional[Dict[str, jax.Array]] = None):
        """Canonical field dict: user fields + zero-init for created fields."""
        fields = {}
        user_fields = user_fields or {}
        for name, sds in self.field_struct.items():
            if name in user_fields:
                arr = jnp.asarray(user_fields[name])
                if arr.shape != sds.shape or arr.dtype != sds.dtype:
                    arr = jnp.broadcast_to(arr, sds.shape).astype(sds.dtype)
                fields[name] = arr
            else:
                fields[name] = jnp.zeros(sds.shape, sds.dtype)
        for name in user_fields:
            if name not in fields:
                fields[name] = jnp.asarray(user_fields[name])
        return fields

    def fn(self, fields: Dict[str, jax.Array], graph=None):
        """Pure program function: fields → (fields, trips[i32[n_iters]]).

        ``graph`` overrides the compile-time graph *data* (same static
        shape), making the graph a traced argument — required when lowering
        against a device mesh (closure arrays would bake in as constants).
        """
        graph = graph if graph is not None else self.graph
        iter_ids = {id(node): i for i, node in enumerate(_iter_nodes(self.prog))}
        trips0 = jnp.zeros((max(self.n_iters, 1),), jnp.int32)
        sched = self.schedule or "pull"
        plans: Dict[int, StepPlan] = {}

        def plan_for(step: ast.Step) -> StepPlan:
            if id(step) not in plans:
                plans[id(step)] = lower_step(
                    step, schedule=sched, byte_costs=self.byte_costs
                )
            return plans[id(step)]

        def run(p: ast.Prog, flds, trips):
            if isinstance(p, ast.Step):
                return StepExecutor(p, graph, plan=plan_for(p))(flds), trips
            if isinstance(p, ast.StopStep):
                return make_stop_fn(p, graph)(flds), trips
            if isinstance(p, ast.Seq):
                for q in p.progs:
                    flds, trips = run(q, flds, trips)
                return flds, trips
            if isinstance(p, ast.Iter):
                idx = iter_ids[id(p)]
                fix = p.fix_fields
                limit = (
                    p.fixed_trips if p.fixed_trips is not None else self.max_iters
                )

                def cond(carry):
                    _, _, changed, k = carry
                    return jnp.logical_and(changed, k < limit)

                def body(carry):
                    f, t, _, k = carry
                    new_f, t = run(p.body, f, t)
                    if fix:
                        changed = jnp.asarray(False)
                        for name in fix:
                            if name not in f:
                                raise CompileError(
                                    f"fix field {name!r} undefined"
                                )
                            changed = jnp.logical_or(
                                changed, jnp.any(new_f[name] != f[name])
                            )
                    else:
                        changed = jnp.asarray(True)  # fixed-trip iteration
                    t = t.at[idx].add(1)
                    return new_f, t, changed, k + 1

                carry = (flds, trips, jnp.asarray(True), jnp.asarray(0, jnp.int32))
                flds, trips, _, _ = jax.lax.while_loop(cond, body, carry)
                return flds, trips
            raise CompileError(f"unknown program node {type(p).__name__}")

        out_fields, trips = run(self.prog, dict(fields), trips0)
        return out_fields, trips

    def run(
        self,
        user_fields: Optional[Dict[str, jax.Array]] = None,
        jit: bool = True,
    ):
        """Execute; returns (fields, trips, superstep counts per regime)."""
        fields = self.init_fields(user_fields)
        fn = jax.jit(self.fn) if jit else self.fn
        out, trips = fn(fields)
        trips_host = [int(x) for x in trips]
        counts = {
            name: cm.count(trips_host) for name, cm in self.cost_models.items()
        }
        return out, trips_host, counts


def _discover_fields(prog, graph, fields_struct):
    """eval_shape pass discovering created fields + stable dtypes."""

    def step_pass(step, fs):
        def f(flds):
            # field discovery is schedule-independent (identical shapes /
            # dtypes under every schedule) — pin pull for determinism
            return StepExecutor(step, graph, schedule="pull")(flds)

        return dict(jax.eval_shape(f, fs))

    def stop_pass(stop, fs):
        def f(flds):
            return make_stop_fn(stop, graph)(flds)

        return dict(jax.eval_shape(f, fs))

    def go(p, fs):
        if isinstance(p, ast.Step):
            return step_pass(p, fs)
        if isinstance(p, ast.StopStep):
            return stop_pass(p, fs)
        if isinstance(p, ast.Seq):
            for q in p.progs:
                fs = go(q, fs)
            return fs
        if isinstance(p, ast.Iter):
            fs2 = go(p.body, fs)
            # one more pass with the enriched struct: dtypes must be stable
            fs3 = go(p.body, fs2)
            if {k: (v.shape, v.dtype) for k, v in fs2.items()} != {
                k: (v.shape, v.dtype) for k, v in fs3.items()
            }:
                raise CompileError(
                    "iteration body changes field shapes/dtypes between "
                    "iterations — not expressible as a fixed carry"
                )
            return fs2
        raise CompileError(f"unknown program node {type(p).__name__}")

    return go(prog, dict(fields_struct))


def compile_program(
    source_or_ast: Union[str, ast.Prog],
    graph,
    initial_fields: Optional[Dict[str, jax.Array]] = None,
    max_iters: int = 100_000,
    schedule: Optional[str] = None,
    byte_costs: Optional[ByteCostModel] = None,
) -> CompiledProgram:
    """Compile Palgol source (or AST) against a graph.

    ``initial_fields`` supplies dtypes/values of pre-existing fields; fields
    created by the program (via ``local F[v] := ...``) are discovered with an
    abstract-evaluation pass and zero-initialized.

    ``schedule`` selects the chain-access lowering the fused trace folds
    in (``"pull"`` — pointer-doubling gather DAG, ``"push"`` — the
    paper-faithful request/combined-reply message schedule, ``"naive"`` —
    per-hop request/reply wire-cost model, ``"auto"`` — per-step cheapest).
    ``None`` means ``"pull"``. ``byte_costs`` (a
    :class:`repro.core.plan.ByteCostModel`, e.g. from
    :func:`repro.graph.partition.byte_cost_model`) makes ``"auto"`` select
    on (supersteps, modeled wire bytes) instead of op count; the STM
    ``auto`` cost model is built with the same costs so the accounting
    tracks the selection.
    """
    prog = (
        palgol_parser.parse(source_or_ast)
        if isinstance(source_or_ast, str)
        else source_or_ast
    )
    if schedule is not None and schedule not in SCHEDULES:
        raise CompileError(
            f"unknown schedule {schedule!r}; expected one of {SCHEDULES}"
        )
    n = graph.n_vertices
    fs: Dict[str, jax.ShapeDtypeStruct] = {
        HALTED: jax.ShapeDtypeStruct((n,), jnp.bool_)
    }
    for name, arr in (initial_fields or {}).items():
        arr = jnp.asarray(arr)
        fs[name] = jax.ShapeDtypeStruct(arr.shape, arr.dtype)
    field_struct = _discover_fields(prog, graph, fs)
    cost_models = stm_mod.superstep_report(prog, byte_costs=byte_costs)
    return CompiledProgram(
        prog=prog,
        graph=graph,
        field_struct=field_struct,
        n_iters=len(_iter_nodes(prog)),
        max_iters=max_iters,
        cost_models=cost_models,
        schedule=schedule,
        byte_costs=byte_costs,
    )
