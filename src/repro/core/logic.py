"""The chain-access logic system (paper §4.1.1) + a TPU-native pull variant.

Patterns
--------
A *chain access pattern* is a tuple of field names applied left-to-right to
the current vertex ``u``: ``()`` is ``u`` itself, ``("D",)`` is ``D[u]``,
``("D", "D")`` is ``D[D[u]]`` (= D²[u]), ``("B", "A")`` is ``A[B[u]]``.
``a ≼ b`` ("a is a subpattern of b") iff ``a`` is a proper prefix of ``b``.

Push mode (paper-faithful)
--------------------------
Propositions ``∀u. K_{v(u)} e(u)`` are pairs ``(v, e)`` of patterns. Axioms:

    1. step(K_u u)      = 0
    2. step(K_u F[u])   = 0                       (for any field F)
    3. K_{w} e ∧ K_{w} v ⇒ K_{v} e                (message passing)

and the recursive cost is

    step(K_v e) = 1 + min_{w ∈ Sub(e,v)} max(step(gen(K_w e)), step(gen(K_w v)))

with ``Sub(a,b)`` = proper prefixes of ``a`` and of ``b``, and ``gen``
(generalize) rewriting ``K_{a} b → K_u (b/a)`` whenever ``a ≼ b``. Memoized;
minimizes the number of *communication rounds* (supersteps), reproducing the
paper's ``D⁴[u]`` in 3 rounds instead of 6 request/reply rounds.

Pull mode (beyond-paper, TPU-native)
------------------------------------
On a shared-address-space machine (sharded arrays + XLA gather collectives) a
remote read is one-sided: no request round and no address propagation are
needed.  If ``X[u] = p(u)`` and ``Y[u] = q(u)`` are knowledge arrays then
``Y[X] = (q∘p)(u)`` costs **one** gather round, so

    rounds(p) = 1 + min over splits p = s ++ t of max(rounds(s), rounds(t))

with rounds(()) = rounds((F,)) = 0 — i.e. pointer doubling: ``D⁴`` in 2
rounds, any depth-k chain in ⌈log₂ k⌉ rounds for uniform chains. Both solvers
share a memo table per compilation so repeated sub-chains are evaluated once
(the paper's "evaluated exactly once even if it appears multiple times").
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

Pattern = Tuple[str, ...]  # field names applied left-to-right from u

INF = 10**9


def is_subpattern(a: Pattern, b: Pattern) -> bool:
    """a ≼ b: b is a consecutive field access starting from a (a proper prefix)."""
    return len(a) < len(b) and b[: len(a)] == a


def proper_prefixes(p: Pattern) -> List[Pattern]:
    return [p[:k] for k in range(len(p))]


def generalize(target: Pattern, expr: Pattern) -> Tuple[Pattern, Pattern]:
    """gen(K_{a} b) = K_u (b/a) when a ≼ b (paper's `generalize`)."""
    if is_subpattern(target, expr) or target == expr[: len(target)]:
        # target is a (possibly improper) prefix of expr
        if expr[: len(target)] == target:
            return ((), expr[len(target):])
    return (target, expr)


# ---------------------------------------------------------------------------
# push mode (paper §4.1.1)


@dataclasses.dataclass(frozen=True)
class PushPlan:
    """Derivation tree of the message-passing axiom.

    ``rounds == 0`` means an axiom (local knowledge). Otherwise the final
    round sends ``expr`` from intermediate ``via`` to ``target``, after the
    two sub-plans complete (they run in parallel: max, not sum).
    """

    target: Pattern
    expr: Pattern
    rounds: int
    via: Optional[Pattern] = None
    value_plan: Optional["PushPlan"] = None
    addr_plan: Optional["PushPlan"] = None


class PushSolver:
    """Memoized DP over propositions (K_v e). One instance per compilation,
    so shared sub-chains across a Palgol step are planned exactly once."""

    def __init__(self):
        self.memo: Dict[Tuple[Pattern, Pattern], PushPlan] = {}
        self._in_progress: set = set()

    def solve(self, target: Pattern, expr: Pattern) -> PushPlan:
        target, expr = generalize(target, expr)
        key = (target, expr)
        if key in self.memo:
            return self.memo[key]
        # axioms
        if target == () and len(expr) <= 1:
            plan = PushPlan(target, expr, 0)
            self.memo[key] = plan
            return plan
        if key in self._in_progress:  # cycle guard (can't happen with Sub, but safe)
            return PushPlan(target, expr, INF)
        self._in_progress.add(key)

        best: Optional[PushPlan] = None
        candidates = set(proper_prefixes(expr)) | set(proper_prefixes(target))
        for w in sorted(candidates, key=len):
            vp = self.solve(w, expr)
            ap = self.solve(w, target)
            rounds = 1 + max(vp.rounds, ap.rounds)
            if best is None or rounds < best.rounds:
                best = PushPlan(target, expr, rounds, via=w, value_plan=vp,
                                addr_plan=ap)
        self._in_progress.discard(key)
        assert best is not None, (target, expr)
        self.memo[key] = best
        return best

    def rounds(self, expr: Pattern) -> int:
        """Communication rounds for ∀u. K_u expr(u)."""
        return self.solve((), expr).rounds


# ---------------------------------------------------------------------------
# pull mode (TPU-native gather staging)


@dataclasses.dataclass(frozen=True)
class PullPlan:
    """Gather DAG node: pattern = suffix ∘ prefix, evaluated as
    ``take(eval(suffix), eval(prefix))``. rounds == 0 for () and single
    fields (local array reads)."""

    pattern: Pattern
    rounds: int
    prefix: Optional["PullPlan"] = None
    suffix: Optional["PullPlan"] = None


class PullSolver:
    """Minimum gather-depth evaluation of chain patterns with CSE.

    The memo table doubles as the common-subexpression cache: the codegen
    evaluates each distinct sub-pattern once per step (paper §4.1.1's
    memoization extension), and the DAG depth equals the number of dependent
    collective rounds under pjit.
    """

    def __init__(self):
        self.memo: Dict[Pattern, PullPlan] = {}

    def solve(self, pattern: Pattern) -> PullPlan:
        if pattern in self.memo:
            return self.memo[pattern]
        if len(pattern) <= 1:
            plan = PullPlan(pattern, 0)
            self.memo[pattern] = plan
            return plan
        best: Optional[PullPlan] = None
        for k in range(1, len(pattern)):
            pre = self.solve(pattern[:k])
            suf = self.solve(pattern[k:])
            rounds = 1 + max(pre.rounds, suf.rounds)
            if best is None or rounds < best.rounds:
                best = PullPlan(pattern, rounds, prefix=pre, suffix=suf)
        assert best is not None
        self.memo[pattern] = best
        return best

    def rounds(self, pattern: Pattern) -> int:
        return self.solve(pattern).rounds

    def schedule(self, patterns) -> List[Pattern]:
        """Topologically-ordered unique sub-patterns needed to evaluate
        ``patterns`` (every chain appears after its prefix/suffix)."""
        order: List[Pattern] = []
        seen = set()

        def visit(plan: PullPlan):
            if plan.pattern in seen:
                return
            if plan.prefix is not None:
                visit(plan.prefix)
                visit(plan.suffix)
            seen.add(plan.pattern)
            order.append(plan.pattern)

        for p in patterns:
            visit(self.solve(p))
        return order


@functools.lru_cache(maxsize=None)
def push_rounds(expr: Pattern) -> int:
    """Convenience: paper-faithful round count for ∀u. K_u expr."""
    return PushSolver().rounds(expr)


@functools.lru_cache(maxsize=None)
def pull_rounds(expr: Pattern) -> int:
    """Beyond-paper: gather-staged round count for the same read."""
    return PullSolver().rounds(expr)
