"""Static analysis of Palgol steps: remote-access patterns + validation.

Recognizes the two remote-read patterns of paper §4.1 —

* **chain access** — ``FieldAccess`` whose index bottoms out at the current
  vertex variable through nested field accesses (``D[D[u]]`` →
  pattern ``("D","D")``);
* **neighborhood communication** — chain accesses starting from ``e.id``
  inside an edge comprehension/loop (``D[e.id]`` → ``("D",)`` at the
  neighbor) —

plus *general reads* ``F[t]`` with a computed index (costed as one
request/reply in push mode, one gather in pull mode), and collects remote
writes and written fields. Also enforces the well-formedness rules the paper
bakes into its syntax (accumulative-only remote writes, non-nested edge
loops, local writes only to the current vertex).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.core import ast
from repro.core import logic


class CompileError(Exception):
    pass


@dataclasses.dataclass
class StepInfo:
    vertex_var: str
    # chain patterns evaluated in vertex context (key: pattern tuple)
    chain_patterns: Set[logic.Pattern] = dataclasses.field(default_factory=set)
    # neighborhood communications: (direction, pattern applied at neighbor)
    nbr_comms: Set[Tuple[str, logic.Pattern]] = dataclasses.field(default_factory=set)
    # number of general (non-chain) remote reads
    general_reads: int = 0
    remote_write_fields: Set[str] = dataclasses.field(default_factory=set)
    local_write_fields: Set[str] = dataclasses.field(default_factory=set)
    fields_read: Set[str] = dataclasses.field(default_factory=set)
    uses_edges: Set[str] = dataclasses.field(default_factory=set)  # directions

    # --- round counts (communication rounds before the main superstep) ----
    def push_read_rounds(self) -> int:
        """Paper-faithful: chain plans + neighborhood sends run in parallel
        (independent message flows share supersteps), so the read phase costs
        the max over individual plans."""
        rounds = 0
        solver = logic.PushSolver()
        for p in self.chain_patterns:
            rounds = max(rounds, solver.rounds(p))
        for _, p in self.nbr_comms:
            # evaluate the chain at the neighbor, then one send along edges
            rounds = max(rounds, solver.rounds(p) + 1 if len(p) > 1 else 1)
        if self.general_reads:
            rounds = max(rounds, 2)  # request + reply
        return rounds

    def pull_read_rounds(self) -> int:
        """Beyond-paper gather staging (one-sided reads)."""
        rounds = 0
        solver = logic.PullSolver()
        for p in self.chain_patterns:
            rounds = max(rounds, solver.rounds(p))
        for _, p in self.nbr_comms:
            rounds = max(rounds, solver.rounds(p) + 1)
        if self.general_reads:
            rounds = max(rounds, 1)
        return rounds

    def read_patterns(self) -> List[logic.Pattern]:
        """Chain patterns the read phase must materialize: vertex-context
        chains plus multi-hop neighborhood chains (evaluated at the
        neighbor before the send). The shared input of every schedule's
        lowering in :mod:`repro.core.plan`."""
        pats = set(self.chain_patterns)
        for _, npat in self.nbr_comms:
            if len(npat) > 1:
                pats.add(npat)
        return sorted(pats)

    def has_remote_writes(self) -> bool:
        return bool(self.remote_write_fields)


def chain_pattern_of(expr: ast.Expr, vertex_var: str) -> Optional[logic.Pattern]:
    """Return the chain pattern if ``expr`` is a consecutive field access
    starting from the current vertex (``u`` → ``()``, ``D[u]`` → ``("D",)``,
    ``D[D[u]]`` → ``("D","D")``), else None."""
    if isinstance(expr, ast.Var) and expr.name == vertex_var:
        return ()
    if isinstance(expr, ast.FieldAccess):
        inner = chain_pattern_of(expr.index, vertex_var)
        if inner is not None:
            return inner + (expr.field,)
    return None


def neighbor_pattern_of(expr: ast.Expr, edge_var: str) -> Optional[logic.Pattern]:
    """Chain pattern starting from ``e.id`` (neighborhood communication)."""
    if (
        isinstance(expr, ast.EdgeProp)
        and expr.edge_var == edge_var
        and expr.prop == "id"
    ):
        return ()
    if isinstance(expr, ast.FieldAccess):
        inner = neighbor_pattern_of(expr.index, edge_var)
        if inner is not None:
            return inner + (expr.field,)
    return None


def analyze_step(step: ast.Step) -> StepInfo:
    info = StepInfo(vertex_var=step.vertex_var)
    let_vars: Set[str] = set()
    remote_ops: Dict[str, str] = {}  # field → its (single) remote combiner

    def visit_expr(e: ast.Expr, edge_var: Optional[str], in_reduce: bool):
        if isinstance(e, ast.FieldAccess):
            info.fields_read.add(e.field)
            pat = chain_pattern_of(e, step.vertex_var)
            if pat is not None:
                if len(pat) > 1:
                    info.chain_patterns.add(pat)
                # len==1 ⇒ own-field read (axiom, free); sub-chains are part
                # of the pattern's plan — do not re-visit the index
                return
            if edge_var is not None:
                npat = neighbor_pattern_of(e, edge_var)
                if npat is not None:
                    info.nbr_comms.add((_current_dir[0], npat))
                    return
            # general read with a computed index
            info.general_reads += 1
            visit_expr(e.index, edge_var, in_reduce)
            return
        if isinstance(e, ast.Var):
            if (
                e.name != step.vertex_var
                and e.name not in let_vars
                and e.name != edge_var
                and e.name != "numV"  # builtin vertex-count constant
            ):
                raise CompileError(f"unbound variable {e.name!r}")
            return
        if isinstance(e, ast.EdgeProp):
            if e.edge_var != edge_var:
                raise CompileError(
                    f".{e.prop} used on {e.edge_var!r} outside its edge loop"
                )
            return
        if isinstance(e, ast.Reduce):
            if in_reduce or edge_var is not None:
                raise CompileError("nested edge comprehensions are not supported")
            _check_edge_range(e.range, step.vertex_var)
            info.uses_edges.add(e.range.direction)
            _current_dir[0] = e.range.direction
            visit_expr(e.body, e.edge_var, True)
            for f in e.filters:
                visit_expr(f, e.edge_var, True)
            _current_dir[0] = None
            return
        if isinstance(e, ast.EdgeList):
            raise CompileError("edge list used outside comprehension/loop range")
        if isinstance(e, ast.Cond):
            visit_expr(e.cond, edge_var, in_reduce)
            visit_expr(e.then, edge_var, in_reduce)
            visit_expr(e.other, edge_var, in_reduce)
            return
        if isinstance(e, ast.BinOp):
            visit_expr(e.left, edge_var, in_reduce)
            visit_expr(e.right, edge_var, in_reduce)
            return
        if isinstance(e, ast.UnOp):
            visit_expr(e.operand, edge_var, in_reduce)
            return
        if isinstance(e, ast.Const):
            return
        raise CompileError(f"unknown expression node {type(e).__name__}")

    _current_dir: List[Optional[str]] = [None]

    def visit_stmts(stmts, edge_var: Optional[str]):
        for s in stmts:
            if isinstance(s, ast.Let):
                visit_expr(s.value, edge_var, False)
                let_vars.add(s.var)
            elif isinstance(s, ast.If):
                visit_expr(s.cond, edge_var, False)
                visit_stmts(s.then, edge_var)
                visit_stmts(s.other, edge_var)
            elif isinstance(s, ast.ForEdges):
                if edge_var is not None:
                    raise CompileError("nested edge loops are not supported")
                _check_edge_range(s.range, step.vertex_var)
                info.uses_edges.add(s.range.direction)
                _current_dir[0] = s.range.direction
                visit_stmts(s.body, s.edge_var)
                _current_dir[0] = None
            elif isinstance(s, ast.LocalWrite):
                if s.index_var and s.index_var != step.vertex_var:
                    raise CompileError(
                        f"local write indexes {s.index_var!r}, not the current "
                        f"vertex {step.vertex_var!r} — use `remote` for that"
                    )
                if edge_var is not None and s.op == ":=":
                    raise CompileError(
                        "plain `:=` inside an edge loop is order-dependent; "
                        "use an accumulative op"
                    )
                visit_expr(s.value, edge_var, False)
                info.local_write_fields.add(s.field)
            elif isinstance(s, ast.RemoteWrite):
                if s.op not in ast.REMOTE_OPS:
                    raise CompileError(f"remote write op {s.op!r} not accumulative")
                prev = remote_ops.get(s.field)
                if prev is not None and prev != s.op:
                    # the paper's order-independence guarantee only holds
                    # when all remote writes to a field share one combiner;
                    # mixing (e.g. += then <?=) is order-dependent — reject
                    raise CompileError(
                        f"field {s.field!r} receives remote writes with "
                        f"mixed combiners ({prev!r} and {s.op!r}) in one "
                        "step — order-dependent, not allowed"
                    )
                remote_ops[s.field] = s.op
                visit_expr(s.target, edge_var, False)
                visit_expr(s.value, edge_var, False)
                info.remote_write_fields.add(s.field)
            else:
                raise CompileError(f"unknown statement {type(s).__name__}")

    visit_stmts(step.body, None)
    return info


def _check_edge_range(rng: ast.EdgeList, vertex_var: str):
    if not (isinstance(rng.vertex, ast.Var) and rng.vertex.name == vertex_var):
        raise CompileError(
            "edge lists may only be traversed from the current vertex "
            f"({vertex_var!r})"
        )


def iter_steps(prog: ast.Prog):
    """Yield all Step/StopStep nodes of a program."""
    if isinstance(prog, (ast.Step, ast.StopStep)):
        yield prog
    elif isinstance(prog, ast.Seq):
        for p in prog.progs:
            yield from iter_steps(p)
    elif isinstance(prog, ast.Iter):
        yield from iter_steps(prog.body)
    else:
        raise CompileError(f"unknown program node {type(prog).__name__}")


def program_fields(prog: ast.Prog) -> Tuple[Set[str], Set[str]]:
    """(fields read, fields written) over the whole program."""
    read: Set[str] = set()
    written: Set[str] = set()
    for step in iter_steps(prog):
        if isinstance(step, ast.StopStep):
            for e in ast.walk_exprs(step.cond):
                if isinstance(e, ast.FieldAccess):
                    read.add(e.field)
            continue
        inf = analyze_step(step)
        read |= inf.fields_read
        written |= inf.local_write_fields | inf.remote_write_fields
    return read, written
