"""State-transition-machine model of compiled Palgol programs (paper §4.2–4.3).

The STM is the *accounting* artifact: it records how many Pregel supersteps
the compiled program costs, under either communication model:

* ``mode="push"`` — paper-faithful: chain access via the PushSolver's
  message-passing plans (request/reply style, minimal rounds), neighborhood
  communication via a combined send superstep. Since the push schedule
  became executable (``repro.core.plan._lower_push``), this counts the
  very plan ops the executors dispatch — same as every other mode.
* ``mode="pull"`` — this framework's dense execution: one-sided gather
  rounds (pointer doubling), strictly ≤ push rounds.

Optimizations modeled exactly as in the paper:

* **state merging** (§4.3.1): adjacent states across a sequence boundary
  merge because the next program's first superstep ignores incoming
  messages (message-independence) — one superstep saved per boundary;
* **iteration fusion** (§4.3.2): when an iteration body begins with a
  remote-reading superstep S₁, S₁ is duplicated into the init state and
  merged into the last body state, removing one superstep per iteration;
* **naive mode**: both optimizations off and chain reads compiled as
  sequential request/reply conversations — the "straightforward" compilation
  the paper compares against (and a stand-in for typical hand-written code
  structure).

Superstep count for a run is a *linear functional* of the per-iteration trip
counts: ``total = constant + Σ_i per_iter_i × trips_i``; ``count()`` takes
the measured trip counts from execution.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import ast
from repro.core import plan as plan_mod


@dataclasses.dataclass(frozen=True)
class State:
    kind: str  # "read" | "main" | "update"
    label: str = ""
    merged: Tuple[str, ...] = ()  # labels merged into this superstep


@dataclasses.dataclass
class STM:
    """Linearized STM: prefix states + loops (each with body states/trips)."""

    states: List  # List[State | Loop]

    def total_states(self) -> int:
        n = 0
        for s in self.states:
            n += 1 if isinstance(s, State) else 0
        return n


@dataclasses.dataclass
class Loop:
    body: List[State]
    iter_index: int  # position in the program's iteration-counter vector
    fused: bool


@dataclasses.dataclass
class CostModel:
    """total supersteps = base + Σ per_iter[i] * trips[i]."""

    base: int
    per_iter: Dict[int, int]
    detail: List[str]

    def count(self, trips: Dict[int, int] | List[int]) -> int:
        if not isinstance(trips, dict):
            trips = dict(enumerate(trips))
        total = self.base
        for i, per in self.per_iter.items():
            total += per * int(trips.get(i, 0))
        return total


def _step_states(
    step: ast.Step,
    mode: str,
    byte_costs: Optional[plan_mod.ByteCostModel] = None,
) -> List[State]:
    if mode not in plan_mod.SCHEDULES:
        raise ValueError(f"unknown mode {mode!r}")
    # every schedule is executable: one State per plan op — the cost model
    # counts the very op list the executors dispatch, so they cannot
    # diverge (push included since repro.core.plan._lower_push landed)
    plan = plan_mod.lower_step(step, schedule=mode, byte_costs=byte_costs)
    states: List[State] = []
    ri = 0
    for op in plan.ops:
        if isinstance(op, plan_mod.ReadRound):
            states.append(State("read", f"rr{ri}"))
            ri += 1
        elif isinstance(op, plan_mod.MainCompute):
            states.append(State("main", "main"))
        else:
            states.append(State("update", "ru"))
    return states


def build_stm(
    prog: ast.Prog,
    mode: str = "push",
    optimize: bool = True,
    byte_costs: Optional[plan_mod.ByteCostModel] = None,
) -> Tuple[STM, CostModel]:
    """Build the STM and its superstep cost model.

    ``optimize=False`` gives the naive compilation (no merging/fusion,
    request-reply chains) used as the manual-style baseline. ``byte_costs``
    only affects ``mode="auto"`` (byte-aware per-step selection, matching
    executors given the same costs).
    """
    iter_counter = [0]

    def build(p: ast.Prog) -> List:
        if isinstance(p, ast.Step):
            return list(_step_states(p, mode, byte_costs))
        if isinstance(p, ast.StopStep):
            return [State("main", "stop")]
        if isinstance(p, ast.Seq):
            out: List = []
            for sub in p.progs:
                states = build(sub)
                if (
                    optimize
                    and out
                    and states
                    and isinstance(out[-1], State)
                    and isinstance(states[0], State)
                ):
                    # §4.3.1 state merging across the sequence boundary
                    left, right = out[-1], states[0]
                    out[-1] = State(
                        left.kind,
                        left.label,
                        merged=left.merged + (right.label,) + right.merged,
                    )
                    states = states[1:]
                out.extend(states)
            return out
        if isinstance(p, ast.Iter):
            body = build(p.body)
            if any(isinstance(b, Loop) for b in body):
                # nested iteration: keep an explicit init state, no fusion
                idx = iter_counter[0]
                iter_counter[0] += 1
                return [State("main", "iter-init"), Loop(body, idx, fused=False)]
            idx = iter_counter[0]
            iter_counter[0] += 1
            fused = (
                optimize
                and body
                and isinstance(body[0], State)
                and body[0].kind == "read"
            )
            if fused:
                # §4.3.2: S1 duplicated into init and merged into S_n
                s1 = body[0]
                rest = body[1:]
                last = rest[-1]
                rest[-1] = State(
                    last.kind, last.label, merged=last.merged + (s1.label,)
                )
                init = State("main", "iter-init", merged=(s1.label,))
                return [init, Loop(rest, idx, fused=True)]
            return [State("main", "iter-init"), Loop(body, idx, fused=False)]
        raise TypeError(type(p))

    flat = build(prog)
    base = 0
    per_iter: Dict[int, int] = {}
    detail: List[str] = []

    def account(items: List, multiplier_key=None):
        nonlocal base
        for it in items:
            if isinstance(it, State):
                if multiplier_key is None:
                    base += 1
                else:
                    per_iter[multiplier_key] = per_iter.get(multiplier_key, 0) + 1
            else:  # Loop
                assert multiplier_key is None or True
                # nested loops: attribute inner states to the inner counter
                account(it.body, it.iter_index)

    account(flat)
    stm = STM(flat)
    for it in flat:
        if isinstance(it, Loop):
            detail.append(
                f"loop#{it.iter_index}: {len([s for s in it.body if isinstance(s, State)])}"
                f" supersteps/iter (fused={it.fused})"
            )
    return stm, CostModel(base, per_iter, detail)


def superstep_report(
    prog: ast.Prog,
    byte_costs: Optional[plan_mod.ByteCostModel] = None,
) -> Dict[str, CostModel]:
    """Cost models under the compilation regimes.

    * ``palgol_push``  — paper-faithful compiler output (logic-system chain
      plans, state merging, iteration fusion);
    * ``palgol_pull``  — this framework's dense schedule (gather staging);
    * ``pull_staged``  — pull schedule without merging/fusion (matches the
      staged BSP executor's actually-executed count);
    * ``push``         — push schedule without merging/fusion (matches
      ``schedule="push"`` execution on every executor);
    * ``naive``        — request/reply chains, no merging/fusion (the
      "straightforward"/manual baseline the paper compares against);
    * ``auto``         — per-step cheapest of pull/push/naive, unfused
      (matches ``schedule="auto"`` execution on both the staged and the
      partitioned executor; pass the same ``byte_costs`` the executor got
      for the byte-aware selection to line up).
    """
    return {
        "palgol_push": build_stm(prog, "push", optimize=True)[1],
        "palgol_pull": build_stm(prog, "pull", optimize=True)[1],
        "pull_staged": build_stm(prog, "pull", optimize=False)[1],
        "push": build_stm(prog, "push", optimize=False)[1],
        "naive": build_stm(prog, "naive", optimize=False)[1],
        "auto": build_stm(
            prog, "auto", optimize=False, byte_costs=byte_costs
        )[1],
    }
