"""State-transition-machine model of compiled Palgol programs (paper §4.2–4.3).

The STM is the *accounting* artifact: it records how many Pregel supersteps
the compiled program costs. Since the program-level plan IR landed
(:func:`repro.core.plan.lower_program` + :func:`repro.core.plan.fuse`),
this module derives **everything** from that IR — it contains no superstep
expansion and no merging/fusion logic of its own:

* ``optimize=False`` counts the unfused :class:`~repro.core.plan.ProgramPlan`
  (one superstep per plan op — what ``run_bsp(..., fuse=False)`` executes);
* ``optimize=True`` counts the :func:`~repro.core.plan.fuse`-rewritten plan
  (§4.3.1 state merging + §4.3.2 iteration fusion — what the executors
  dispatch by default), so optimized accounting equals optimized execution
  by construction.

``mode`` is the chain-access schedule (``pull``/``push``/``naive``/``auto``,
see :mod:`repro.core.plan`). Superstep count for a run is a *linear
functional* of the per-iteration trip counts:
``total = base + Σ_i per_iter_i × trips_i``; ``count()`` takes the measured
trip counts from execution.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import ast
from repro.core import plan as plan_mod


@dataclasses.dataclass(frozen=True)
class State:
    kind: str  # "read" | "main" | "update"
    label: str = ""
    merged: Tuple[str, ...] = ()  # labels merged into this superstep


@dataclasses.dataclass
class STM:
    """Linearized STM: prefix states + loops (each with body states/trips)."""

    states: List  # List[State | Loop]

    def total_states(self) -> int:
        n = 0
        for s in self.states:
            n += 1 if isinstance(s, State) else 0
        return n


@dataclasses.dataclass
class Loop:
    body: List[State]
    iter_index: int  # position in the program's iteration-counter vector
    fused: bool


@dataclasses.dataclass
class CostModel:
    """total supersteps = base + Σ per_iter[i] * trips[i]."""

    base: int
    per_iter: Dict[int, int]
    detail: List[str]

    def count(self, trips: Dict[int, int] | List[int]) -> int:
        if not isinstance(trips, dict):
            trips = dict(enumerate(trips))
        total = self.base
        for i, per in self.per_iter.items():
            total += per * int(trips.get(i, 0))
        return total


def _part_label(ref: plan_mod.OpRef, i: int) -> Tuple[str, str]:
    """(kind, label) of one superstep part, for the STM rendering."""
    op = ref.op
    if isinstance(op, plan_mod.ReadRound):
        return "read", f"rr{i}"
    if isinstance(op, plan_mod.RemoteUpdate):
        return "update", "ru"
    if isinstance(op, plan_mod.IterInit):
        return "main", "iter-init"
    if isinstance(op, plan_mod.StopOp):
        return "main", "stop"
    return "main", "main"


def _to_states(items) -> List:
    out: List = []
    for it in items:
        if isinstance(it, plan_mod.Superstep):
            kinds_labels = [
                _part_label(ref, i) for i, ref in enumerate(it.parts)
            ]
            kind, label = kinds_labels[0]
            merged = tuple(lbl for _, lbl in kinds_labels[1:])
            out.append(State(kind, label, merged=merged))
        else:
            out.append(Loop(_to_states(it.body), it.iter_index, it.fused))
    return out


def build_stm(
    prog: ast.Prog,
    mode: str = "push",
    optimize: bool = True,
    byte_costs: Optional[plan_mod.ByteCostModel] = None,
) -> Tuple[STM, CostModel]:
    """Build the STM and its superstep cost model off the program plan.

    ``optimize=True`` counts the fused plan (state merging + iteration
    fusion — the default execution schedule); ``optimize=False`` counts the
    unfused plan (``fuse=False`` execution / the manual-style baseline when
    combined with ``mode="naive"``). ``byte_costs`` only affects
    ``mode="auto"`` (byte-aware per-step selection, matching executors
    given the same costs).
    """
    pp = plan_mod.lower_program(prog, schedule=mode, byte_costs=byte_costs)
    if optimize:
        pp = plan_mod.fuse(pp)
    base, per_iter, detail = pp.cost()
    return STM(_to_states(pp.items)), CostModel(base, per_iter, detail)


def superstep_report(
    prog: ast.Prog,
    byte_costs: Optional[plan_mod.ByteCostModel] = None,
) -> Dict[str, CostModel]:
    """Cost models under the compilation regimes.

    * ``palgol_push``  — paper-faithful compiler output (push chain plans,
      state merging, iteration fusion) — what ``schedule="push"`` executes
      by default (``fuse=True``);
    * ``palgol_pull``  — this framework's dense schedule, fused — what
      ``schedule="pull"``/default executes (``fuse=True``);
    * ``pull_staged``  — pull schedule without merging/fusion (matches
      ``fuse=False`` execution on every executor);
    * ``push``         — push schedule, unfused (``schedule="push",
      fuse=False``);
    * ``naive``        — request/reply chains, no merging/fusion (the
      "straightforward"/manual baseline the paper compares against);
    * ``auto``         — per-step cheapest of pull/push/naive, unfused;
    * ``fused_pull`` / ``fused_push`` — aliases of the ``palgol_*`` keys;
    * ``fused_naive`` / ``fused_auto`` — the remaining schedules under the
      fuse pass, completing the (schedule × fuse) count matrix every
      ``run_bsp(schedule=s, fuse=f)`` cell can be checked against (pass the
      same ``byte_costs`` the executor got so ``auto`` lines up).
    """
    fused_pull = build_stm(prog, "pull", optimize=True)[1]
    fused_push = build_stm(prog, "push", optimize=True)[1]
    return {
        "palgol_push": fused_push,
        "palgol_pull": fused_pull,
        "pull_staged": build_stm(prog, "pull", optimize=False)[1],
        "push": build_stm(prog, "push", optimize=False)[1],
        "naive": build_stm(prog, "naive", optimize=False)[1],
        "auto": build_stm(
            prog, "auto", optimize=False, byte_costs=byte_costs
        )[1],
        "fused_pull": fused_pull,
        "fused_push": fused_push,
        "fused_naive": build_stm(prog, "naive", optimize=True)[1],
        "fused_auto": build_stm(
            prog, "auto", optimize=True, byte_costs=byte_costs
        )[1],
    }
