"""Naive reference interpreter — the semantic oracle for the compiler.

Executes Palgol programs one vertex at a time in pure Python/numpy, directly
following the paper's §3.1 semantics:

* LC phase: every vertex runs the block; reads see the *input* fields; local
  writes read-modify-write an intermediate copy of the vertex's own row;
* RU phase: remote accumulative writes collected during LC are applied to the
  intermediate copy (order-independent by construction);
* fixed-point iteration repeats until the fix fields stabilize;
* halted vertices skip computation and reject incoming remote writes, but
  remain readable.

This is O(V·E) Python — only for small test graphs. The property tests
(hypothesis) compare the dense compiled executor against this oracle on
random graphs and random programs.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import ast
from repro.core import parser as palgol_parser
from repro.core.analysis import CompileError

_IDENT = {
    "minimum": math.inf,
    "maximum": -math.inf,
    "sum": 0,
    "prod": 1,
    "and": True,
    "or": False,
}

_INT_IDENT = {"minimum": np.iinfo(np.int32).max, "maximum": np.iinfo(np.int32).min}


class _Adjacency:
    """Host-side adjacency lists built from the dense Graph struct."""

    def __init__(self, graph):
        self.n = graph.n_vertices
        src = np.asarray(graph.src)
        dst = np.asarray(graph.dst)
        w = np.asarray(graph.weight)
        m = np.asarray(graph.edge_mask)
        self.in_adj: List[List[Tuple[int, float]]] = [[] for _ in range(self.n)]
        self.out_adj: List[List[Tuple[int, float]]] = [[] for _ in range(self.n)]
        for s, d, ww, mm in zip(src, dst, w, m):
            if not mm:
                continue
            self.in_adj[d].append((int(s), float(ww)))
            self.out_adj[s].append((int(d), float(ww)))

    def edges(self, direction: str, u: int):
        if direction in ("in", "nbr"):
            return self.in_adj[u]
        return self.out_adj[u]


def interpret(
    source_or_ast,
    graph,
    initial_fields: Optional[Dict[str, np.ndarray]] = None,
    max_iters: int = 100_000,
):
    """Run the oracle; returns (fields dict of numpy arrays, trips list)."""
    prog = (
        palgol_parser.parse(source_or_ast)
        if isinstance(source_or_ast, str)
        else source_or_ast
    )
    adj = _Adjacency(graph)
    n = adj.n
    fields: Dict[str, np.ndarray] = {"_halted": np.zeros(n, bool)}
    for name, arr in (initial_fields or {}).items():
        fields[name] = np.array(arr)
    trips: List[int] = []

    def field_read(flds, name, idx):
        if name == "Id":
            return int(idx)
        if name not in flds:
            raise CompileError(f"read of undefined field {name!r}")
        i = int(idx)
        if i < 0 or i >= n:
            i = min(max(i, 0), n - 1)  # clip, matching dense gather
        return flds[name][i]

    def eval_expr(e, u, env, old):
        if isinstance(e, ast.Const):
            return math.inf if e.value == "inf" else e.value
        if isinstance(e, ast.Var):
            if e.name == "numV":
                return n
            return env[e.name]
        if isinstance(e, ast.EdgeProp):
            nbr, w = env[("edge", e.edge_var)]
            return nbr if e.prop == "id" else w
        if isinstance(e, ast.FieldAccess):
            idx = eval_expr(e.index, u, env, old)
            return field_read(old, e.field, idx)
        if isinstance(e, ast.Cond):
            return (
                eval_expr(e.then, u, env, old)
                if eval_expr(e.cond, u, env, old)
                else eval_expr(e.other, u, env, old)
            )
        if isinstance(e, ast.BinOp):
            lhs = eval_expr(e.left, u, env, old)
            rhs = eval_expr(e.right, u, env, old)
            return _apply_binop(e.op, lhs, rhs)
        if isinstance(e, ast.UnOp):
            x = eval_expr(e.operand, u, env, old)
            return (not x) if e.op == "!" else -x
        if isinstance(e, ast.Reduce):
            items = []
            # identity dtype must come from *static* typing, not from the
            # (possibly empty) item list — mirrors the dense executor, where
            # the segment-reduce identity is the field dtype's inf/intmax.
            int_valued = not _is_float_expr(e.body, old, env)
            for nbr, w in adj.edges(e.range.direction, u):
                env2 = dict(env)
                env2[("edge", e.edge_var)] = (nbr, w)
                env2[e.edge_var] = None  # marks the loop var as bound
                if all(eval_expr(f, u, env2, old) for f in e.filters):
                    if e.func == "count":
                        items.append(1)
                    elif e.func in ("argmin", "argmax"):
                        items.append((eval_expr(e.body, u, env2, old), nbr))
                    else:
                        items.append(eval_expr(e.body, u, env2, old))
            return _reduce(e.func, items, int_valued, sentinel=n)
        raise CompileError(f"cannot evaluate {type(e).__name__}")

    def exec_stmts(stmts, u, env, old, new, remote_msgs, edge_ctx):
        for s in stmts:
            if isinstance(s, ast.Let):
                env[s.var] = eval_expr(s.value, u, env, old)
            elif isinstance(s, ast.If):
                if eval_expr(s.cond, u, env, old):
                    exec_stmts(s.then, u, env, old, new, remote_msgs, edge_ctx)
                elif s.other:
                    exec_stmts(s.other, u, env, old, new, remote_msgs, edge_ctx)
            elif isinstance(s, ast.ForEdges):
                for nbr, w in adj.edges(s.range.direction, u):
                    env2 = dict(env)
                    env2[("edge", s.edge_var)] = (nbr, w)
                    exec_stmts(s.body, u, env2, old, new, remote_msgs, True)
            elif isinstance(s, ast.LocalWrite):
                val = eval_expr(s.value, u, env, old)
                if s.field not in new:
                    if s.op != ":=":
                        raise CompileError(
                            f"field {s.field!r} first written accumulatively"
                        )
                    # dtype from the *expression* (matches jnp promotion in
                    # the dense executor), not this vertex's branch value:
                    # `(Id[v]==0 ? 0 : inf)` is float even where it yields 0
                    if _is_float_expr(s.value, old, env):
                        dtype = np.float32
                    else:
                        dtype = _infer_dtype(val)
                    new[s.field] = np.zeros(n, dtype)
                    old.setdefault(s.field, np.zeros(n, dtype))
                cur = new[s.field][u]
                new[s.field][u] = _apply_write(s.op, cur, val, new[s.field].dtype)
            elif isinstance(s, ast.RemoteWrite):
                tgt = int(eval_expr(s.target, u, env, old))
                val = eval_expr(s.value, u, env, old)
                remote_msgs.append((s.field, s.op, tgt, val))
            else:
                raise CompileError(f"unknown statement {type(s).__name__}")

    def run_step(step: ast.Step):
        old = {k: v.copy() for k, v in fields.items()}
        new = {k: v.copy() for k, v in fields.items()}
        remote_msgs: List[Tuple[str, str, int, object]] = []
        halted = fields["_halted"]
        for u in range(n):
            if halted[u]:
                continue
            env = {step.vertex_var: u}
            exec_stmts(step.body, u, env, old, new, remote_msgs, False)
        for f, op, tgt, val in remote_msgs:
            if tgt < 0 or tgt >= n or halted[tgt]:
                continue
            if f not in new:
                raise CompileError(f"remote write to undefined field {f!r}")
            cur = new[f][tgt]
            new[f][tgt] = _apply_write(op, cur, val, new[f].dtype)
        fields.clear()
        fields.update(new)

    def run_stop(stop: ast.StopStep):
        old = {k: v.copy() for k, v in fields.items()}
        halted = fields["_halted"].copy()
        for u in range(n):
            if halted[u]:
                continue
            env = {stop.vertex_var: u}
            if eval_expr(stop.cond, u, env, old):
                halted[u] = True
        fields["_halted"] = halted

    def run(p):
        if isinstance(p, ast.Step):
            run_step(p)
        elif isinstance(p, ast.StopStep):
            run_stop(p)
        elif isinstance(p, ast.Seq):
            for q in p.progs:
                run(q)
        elif isinstance(p, ast.Iter):
            trips.append(0)
            slot = len(trips) - 1
            limit = p.fixed_trips if p.fixed_trips is not None else max_iters
            for _ in range(limit):
                before = {f: fields[f].copy() for f in p.fix_fields if f in fields}
                run(p.body)
                trips[slot] += 1
                if p.fix_fields:
                    stable = all(
                        f in before and np.array_equal(before[f], fields[f])
                        for f in p.fix_fields
                    )
                    if stable:
                        break
        else:
            raise CompileError(f"unknown program node {type(p).__name__}")

    run(prog)
    return fields, trips


def _is_float_expr(e, fields, env) -> bool:
    """Static-ish float-ness of a reduce body (for the empty-list identity)."""
    if isinstance(e, ast.Const):
        return isinstance(e.value, float) or e.value == "inf"
    if isinstance(e, ast.Var):
        v = env.get(e.name)
        return isinstance(v, (float, np.floating))
    if isinstance(e, ast.EdgeProp):
        return e.prop == "w"
    if isinstance(e, ast.FieldAccess):
        arr = fields.get(e.field)
        return arr is not None and np.issubdtype(arr.dtype, np.floating)
    if isinstance(e, ast.Cond):
        return _is_float_expr(e.then, fields, env) or _is_float_expr(
            e.other, fields, env
        )
    if isinstance(e, ast.BinOp):
        if e.op == "/":
            return True
        if e.op in ("==", "!=", "<", "<=", ">", ">=", "&&", "||"):
            return False
        return _is_float_expr(e.left, fields, env) or _is_float_expr(
            e.right, fields, env
        )
    if isinstance(e, ast.UnOp):
        return e.op != "!" and _is_float_expr(e.operand, fields, env)
    return False


def _infer_dtype(val):
    if isinstance(val, (bool, np.bool_)):
        return np.bool_
    if isinstance(val, (int, np.integer)):
        return np.int32
    return np.float32


def _is_int(v):
    return isinstance(v, (int, np.integer)) and not isinstance(
        v, (bool, np.bool_)
    )


def _wrap_i32(v):
    """int32 wraparound — field arithmetic IS int32 in the dense runtime,
    so the oracle models the same two's-complement semantics (matters when
    arithmetic touches the empty-reduce identity INT32_MAX/MIN)."""
    return int((int(v) + 2**31) % 2**32 - 2**31)


def _apply_binop(op, lhs, rhs):
    wrap = _is_int(lhs) and _is_int(rhs)
    if op == "+":
        return _wrap_i32(lhs + rhs) if wrap else lhs + rhs
    if op == "-":
        return _wrap_i32(lhs - rhs) if wrap else lhs - rhs
    if op == "*":
        return _wrap_i32(lhs * rhs) if wrap else lhs * rhs
    if op == "/":
        if rhs == 0:
            return math.inf if lhs > 0 else (-math.inf if lhs < 0 else math.nan)
        return lhs / rhs
    if op == "%":
        return lhs % rhs
    if op == "==":
        return lhs == rhs
    if op == "!=":
        return lhs != rhs
    if op == "<":
        return lhs < rhs
    if op == "<=":
        return lhs <= rhs
    if op == ">":
        return lhs > rhs
    if op == ">=":
        return lhs >= rhs
    if op == "&&":
        return bool(lhs) and bool(rhs)
    if op == "||":
        return bool(lhs) or bool(rhs)
    raise CompileError(f"unknown operator {op!r}")


def _apply_write(op, cur, val, dtype):
    wrap = np.issubdtype(dtype, np.integer) and _is_int(val)
    if op == ":=":
        out = val
    elif op == "+=":
        out = _wrap_i32(cur + val) if wrap else cur + val
    elif op == "*=":
        out = _wrap_i32(cur * val) if wrap else cur * val
    elif op == "<?=":
        out = min(cur, val)
    elif op == ">?=":
        out = max(cur, val)
    elif op == "||=":
        out = bool(cur) or bool(val)
    elif op == "&&=":
        out = bool(cur) and bool(val)
    else:
        raise CompileError(f"unknown write op {op!r}")
    if np.issubdtype(dtype, np.integer) and isinstance(out, float):
        if math.isinf(out):
            out = np.iinfo(dtype).max if out > 0 else np.iinfo(dtype).min
    if np.issubdtype(dtype, np.integer) and _is_int(out):
        out = _wrap_i32(out)
    return out


def _reduce(func, items, int_valued, sentinel=None):
    if func == "count":
        return len(items)
    if func == "argmin":
        if not items:
            return sentinel  # matches the dense executor's out-of-range id
        best = min(v for v, _ in items)
        return min(i for v, i in items if v == best)
    if func == "argmax":
        if not items:
            return sentinel
        best = max(v for v, _ in items)
        return min(i for v, i in items if v == best)
    if not items:
        ident = _IDENT[func]
        if func in _INT_IDENT and int_valued:
            return _INT_IDENT[func]
        return ident
    if func == "minimum":
        return min(items)
    if func == "maximum":
        return max(items)
    if func == "sum":
        return sum(items)
    if func == "prod":
        out = 1
        for v in items:
            out *= v
        return out
    if func == "and":
        return all(items)
    if func == "or":
        return any(items)
    raise CompileError(f"unknown reduce {func!r}")
