"""EmbeddingBag — JAX has no native one; built from take + segment_sum.

Two layouts:
* fixed-width bags  [B, H] indices (+ optional weights): gather + masked
  reduce along H — the vectorized TPU-friendly form;
* ragged bags       flat indices [T] + bag offsets — gather + segment_sum
  (torch ``nn.EmbeddingBag``-equivalent semantics).

The Pallas kernel ``repro.kernels.embedding_bag`` accelerates the
fixed-width form with scalar-prefetch row gathering.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.graph import ops as gops


def embedding_bag(
    table: jax.Array,  # [V, D]
    indices: jax.Array,  # [B, H]
    weights: Optional[jax.Array] = None,  # [B, H]
    mask: Optional[jax.Array] = None,  # [B, H]
    mode: str = "sum",
) -> jax.Array:
    """Fixed-width multi-hot bag lookup → [B, D]."""
    vals = jnp.take(table, indices, axis=0, mode="clip")  # [B, H, D]
    if weights is not None:
        vals = vals * weights[..., None].astype(vals.dtype)
    if mask is not None:
        vals = vals * mask[..., None].astype(vals.dtype)
    if mode == "sum":
        return jnp.sum(vals, axis=1)
    if mode == "mean":
        denom = (
            jnp.sum(mask, axis=1, keepdims=True).astype(vals.dtype)
            if mask is not None
            else jnp.asarray(indices.shape[1], vals.dtype)
        )
        return jnp.sum(vals, axis=1) / jnp.maximum(denom, 1.0)
    if mode == "max":
        if mask is not None:
            vals = jnp.where(mask[..., None], vals, -jnp.inf)
        out = jnp.max(vals, axis=1)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(mode)


def embedding_bag_ragged(
    table: jax.Array,  # [V, D]
    flat_indices: jax.Array,  # [T]
    bag_ids: jax.Array,  # [T]  (sorted bag id per index)
    n_bags: int,
    weights: Optional[jax.Array] = None,  # [T]
    mode: str = "sum",
) -> jax.Array:
    """Ragged bag lookup (CSR-offsets style) → [n_bags, D]."""
    vals = jnp.take(table, flat_indices, axis=0, mode="clip")  # [T, D]
    if weights is not None:
        vals = vals * weights[:, None].astype(vals.dtype)
    if mode == "sum":
        return gops.segment_reduce(vals, bag_ids, n_bags, "sum",
                                   indices_are_sorted=True)
    if mode == "mean":
        s = gops.segment_reduce(vals, bag_ids, n_bags, "sum",
                                indices_are_sorted=True)
        cnt = gops.segment_reduce(
            jnp.ones_like(flat_indices, vals.dtype), bag_ids, n_bags, "sum",
            indices_are_sorted=True,
        )
        return s / jnp.maximum(cnt[:, None], 1.0)
    if mode == "max":
        out = gops.segment_reduce(vals, bag_ids, n_bags, "max",
                                  indices_are_sorted=True)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(mode)
