from repro.models.recsys.config import AutoIntConfig
from repro.models.recsys import autoint, embedding

__all__ = ["AutoIntConfig", "autoint", "embedding"]
