"""AutoInt configuration (arXiv:1810.11921)."""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class AutoIntConfig:
    name: str
    n_fields: int = 39
    embed_dim: int = 16
    n_attn_layers: int = 3
    n_heads: int = 2
    d_attn: int = 32  # total attention width (d_head = d_attn / n_heads)
    vocab_per_field: int = 1_000_000  # hashed vocabulary rows per field
    mlp_dims: Tuple[int, ...] = (400, 400)
    param_dtype: str = "float32"

    @property
    def d_head(self) -> int:
        return self.d_attn // self.n_heads
