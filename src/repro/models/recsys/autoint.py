"""AutoInt: self-attentive feature interaction over field embeddings.

The hot path at serving scale is the embedding lookup (39 fields × 10⁶-row
tables); interaction is 3 small self-attention layers over the 39 field
"tokens", then an MLP head. ``retrieval_score`` scores one query against
N candidates as a single batched matmul (no loop).
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import dense_init
from repro.models.recsys.config import AutoIntConfig


def init(key, cfg: AutoIntConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4 + cfg.n_attn_layers)
    d, da = cfg.embed_dim, cfg.d_attn
    params: Dict[str, Any] = {
        # one stacked table [F, V, D] — sharded over V at scale
        "tables": (
            jax.random.normal(ks[0], (cfg.n_fields, cfg.vocab_per_field, d))
            * 0.01
        ).astype(dtype),
    }
    layers = []
    d_in = d
    for i in range(cfg.n_attn_layers):
        k1, k2, k3, k4, k5 = jax.random.split(ks[1 + i], 5)
        layers.append(
            {
                "wq": dense_init(k1, d_in, da, dtype),
                "wk": dense_init(k2, d_in, da, dtype),
                "wv": dense_init(k3, d_in, da, dtype),
                "w_res": dense_init(k4, d_in, da, dtype),
            }
        )
        d_in = da
    params["attn"] = layers
    mlp = []
    din = cfg.n_fields * da
    kmlp = jax.random.split(ks[-2], len(cfg.mlp_dims) + 1)
    for i, dd in enumerate(cfg.mlp_dims):
        mlp.append(
            {"w": dense_init(kmlp[i], din, dd, dtype), "b": jnp.zeros((dd,), dtype)}
        )
        din = dd
    params["mlp"] = mlp
    params["head"] = dense_init(kmlp[-1], din, 1, dtype)
    return params


def abstract_params(cfg: AutoIntConfig):
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


def _interact(params, emb, cfg: AutoIntConfig):
    """emb: [B, F, D] → interaction representation [B, F, d_attn]."""
    x = emb
    for lp in params["attn"]:
        b, f, d = x.shape
        q = (x @ lp["wq"]).reshape(b, f, cfg.n_heads, cfg.d_head)
        k = (x @ lp["wk"]).reshape(b, f, cfg.n_heads, cfg.d_head)
        v = (x @ lp["wv"]).reshape(b, f, cfg.n_heads, cfg.d_head)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
        s = s / math.sqrt(cfg.d_head)
        a = jax.nn.softmax(s, axis=-1).astype(x.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", a, v).reshape(b, f, -1)
        x = jax.nn.relu(o + x @ lp["w_res"])
    return x


def lookup(params, indices: jax.Array) -> jax.Array:
    """indices [B, F] → embeddings [B, F, D] via per-field tables.

    Realized as a single gather into the stacked [F, V, D] table with
    field-offset flattening — the EmbeddingBag hot path (H=1 bags). Multi-hot
    fields route through ``embedding_bag`` with the same table rows.
    """
    f, v, d = params["tables"].shape
    flat_tables = params["tables"].reshape(f * v, d)
    offsets = (jnp.arange(f, dtype=jnp.int32) * v)[None, :]  # [1, F]
    flat_idx = indices + offsets  # [B, F]
    return jnp.take(flat_tables, flat_idx, axis=0, mode="clip")


def forward(params, batch, cfg: AutoIntConfig):
    """batch: {"fields": [B, F] int32} → logits [B]."""
    emb = lookup(params, batch["fields"])  # [B, F, D]
    x = _interact(params, emb, cfg)
    h = x.reshape(x.shape[0], -1)
    for lp in params["mlp"]:
        h = jax.nn.relu(h @ lp["w"] + lp["b"])
    return (h @ params["head"])[:, 0]


def loss_fn(params, batch, cfg: AutoIntConfig):
    logits = forward(params, batch, cfg)
    return common.sigmoid_bce(logits, batch["labels"])


def query_embedding(params, batch, cfg: AutoIntConfig):
    """User-side tower for retrieval: pooled interaction output [B, d_attn]."""
    emb = lookup(params, batch["fields"])
    x = _interact(params, emb, cfg)
    return jnp.mean(x, axis=1)  # [B, d_attn]


def retrieval_score(params, batch, cfg: AutoIntConfig, top_k: int = 100):
    """Score one query batch against N candidates: batched dot + top-k.

    batch: {"fields": [B, F], "candidates": [N, d_attn]} → (scores, ids).
    """
    q = query_embedding(params, batch, cfg)  # [B, da]
    scores = q @ batch["candidates"].T  # [B, N]
    return jax.lax.top_k(scores, top_k)


def input_specs(cfg: AutoIntConfig, kind: str, batch: int, n_candidates: int = 0):
    i32, f32 = jnp.int32, jnp.float32
    spec = {"fields": jax.ShapeDtypeStruct((batch, cfg.n_fields), i32)}
    if kind == "train":
        spec["labels"] = jax.ShapeDtypeStruct((batch,), f32)
    if kind == "retrieval":
        spec["candidates"] = jax.ShapeDtypeStruct((n_candidates, cfg.d_attn), f32)
    return spec
