"""Transformer configuration (covers all assigned LM architectures)."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    d_ff_shared: Optional[int] = None  # defaults to d_ff_expert × n_shared
    capacity_factor: float = 1.25
    router_dtype: str = "float32"

    @property
    def shared_ff(self) -> int:
        if self.d_ff_shared is not None:
            return self.d_ff_shared
        return self.d_ff_expert * max(self.n_shared_experts, 1)


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None
    qk_norm: bool = False
    qkv_bias: bool = False
    swa_window: Optional[int] = None  # sliding-window attention (h2o-danube)
    rope_theta: float = 1_000_000.0
    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # attention implementation: "dense" scores or "chunked" online-softmax
    attn_impl: str = "chunked"
    attn_chunk_q: int = 1024
    attn_chunk_kv: int = 1024
    remat: bool = True
    scan_unroll: int = 1  # layer-scan unroll (dry-run flops probes use L=2)

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_params(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and roofline)."""
        d, hd = self.d_model, self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (
            self.n_heads * hd
        ) * d
        if self.moe is None:
            ffn = 3 * d * self.d_ff
        else:
            ffn = self.moe.n_experts * 3 * d * self.moe.d_ff_expert
            ffn += d * self.moe.n_experts  # router
            if self.moe.n_shared_experts:
                ffn += 3 * d * self.moe.shared_ff
        norms = 2 * d
        per_layer = attn + ffn + norms
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d

    def n_active_params(self) -> int:
        """Active (per-token) parameters — MoE counts top_k + shared only."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        hd = self.head_dim
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (
            self.n_heads * hd
        ) * d
        ffn = self.moe.top_k * 3 * d * self.moe.d_ff_expert
        ffn += d * self.moe.n_experts
        if self.moe.n_shared_experts:
            ffn += 3 * d * self.moe.shared_ff
        per_layer = attn + ffn + 2 * d
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + d
