"""Attention: GQA + RoPE + qk-norm + sliding window; dense & chunked impls.

The chunked implementation is the pure-JAX flash-attention analogue (online
softmax over KV chunks via ``lax.scan``) — O(S·chunk) memory instead of
O(S²), required for ``prefill_32k``. The Pallas kernel in
``repro.kernels.flash_attention`` is the TPU-optimized version of the same
contraction; this module is its reference semantics and the GSPMD-partitioned
fallback.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, Dh]; positions: broadcastable to [..., S]."""
    freqs = rope_frequencies(x.shape[-1], theta)  # [Dh/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, Dh/2]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, Dh/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """[B, S, Hkv, Dh] → [B, S, Hkv*n_rep, Dh] (GQA broadcast)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """Additive mask bias [..., Sq, Sk] from position vectors."""
    diff = q_pos[..., :, None] - k_pos[..., None, :]  # q - k
    ok = jnp.ones(diff.shape, jnp.bool_)
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    return jnp.where(ok, 0.0, NEG_INF)


def attention_dense(
    q: jax.Array,  # [B, Sq, H, Dh]
    k: jax.Array,  # [B, Sk, Hkv, Dh]
    v: jax.Array,  # [B, Sk, Hkv, Dh]
    q_pos: jax.Array,  # [B, Sq] or [Sq]
    k_pos: jax.Array,  # [B, Sk] or [Sk]
    causal: bool = True,
    window: Optional[int] = None,
    kv_mask: Optional[jax.Array] = None,  # [B, Sk] valid-KV mask (decode)
) -> jax.Array:
    b, sq, h, dh = q.shape
    n_rep = h // k.shape[2]
    k = repeat_kv(k, n_rep)
    v = repeat_kv(v, n_rep)
    scale = dh**-0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if q_pos.ndim == 1:
        q_pos = q_pos[None]
    if k_pos.ndim == 1:
        k_pos = k_pos[None]
    bias = _mask_bias(q_pos[:, None, :], k_pos[:, None, :], causal, window)
    logits = logits + bias
    if kv_mask is not None:
        logits = jnp.where(kv_mask[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    chunk_q: int = 1024,  # kept for API compat; q stays unchunked
    chunk_kv: int = 1024,
    kv_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Flash-style attention: online softmax over KV chunks, custom VJP.

    Memory is O(Sq × chunk_kv) for the running block instead of O(Sq × Sk);
    the backward pass recomputes per-chunk probabilities from the saved
    logsumexp (standard FlashAttention recomputation), so nothing O(S²) is
    ever materialized — this is the GSPMD-partitioned reference semantics of
    the Pallas ``flash_attention`` kernel.
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    chunk_kv = min(chunk_kv, sk)
    pk = (-sk) % chunk_kv
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (b, sq))
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (b, sk))
    if kv_mask is None:
        kv_mask = jnp.ones((b, sk), jnp.bool_)
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pk)))
        kv_mask = jnp.pad(kv_mask, ((0, 0), (0, pk)))
    out = _flash(q, k, v, q_pos, k_pos, kv_mask, causal, window, chunk_kv)
    return out


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _flash(q, k, v, q_pos, k_pos, kv_mask, causal, window, chunk_kv):
    out, _ = _flash_fwd_impl(
        q, k, v, q_pos, k_pos, kv_mask, causal, window, chunk_kv
    )
    return out


def _chunked(x, ck):
    # [b, sk, ...] -> [nk, b, ck, ...]
    b, sk = x.shape[:2]
    return jnp.moveaxis(x.reshape((b, sk // ck, ck) + x.shape[2:]), 1, 0)


def _flash_fwd_impl(q, k, v, q_pos, k_pos, kv_mask, causal, window, ck):
    b, sq, h, dh = q.shape
    n_rep = h // k.shape[2]
    scale = dh**-0.5

    def kv_step(carry, kv):
        acc, m, lsum = carry
        ki, vi, kpi, kmi = kv
        ki = repeat_kv(ki, n_rep)
        vi = repeat_kv(vi, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, ki).astype(jnp.float32) * scale
        s = s + _mask_bias(q_pos[:, None, :], kpi[:, None, :], causal, window)
        s = jnp.where(kmi[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = lsum * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(q.dtype), vi
        ).astype(jnp.float32)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, lsum), _ = jax.lax.scan(
        kv_step,
        (acc0, m0, l0),
        (_chunked(k, ck), _chunked(v, ck), _chunked(k_pos, ck),
         _chunked(kv_mask, ck)),
    )
    l_safe = jnp.maximum(lsum, 1e-30)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    out = jnp.transpose(out, (0, 2, 1, 3))  # [b, sq, h, dh]
    lse = m + jnp.log(l_safe)  # [b, h, sq]
    return out, lse


def _flash_fwd(q, k, v, q_pos, k_pos, kv_mask, causal, window, ck):
    out, lse = _flash_fwd_impl(
        q, k, v, q_pos, k_pos, kv_mask, causal, window, ck
    )
    return out, (q, k, v, q_pos, k_pos, kv_mask, out, lse)


def _flash_bwd(causal, window, ck, res, g):
    q, k, v, q_pos, k_pos, kv_mask, out, lse = res
    b, sq, h, dh = q.shape
    hkv = k.shape[2]
    n_rep = h // hkv
    scale = dh**-0.5
    g = g.astype(jnp.float32)  # [b, sq, h, dh]
    gt = jnp.transpose(g, (0, 2, 1, 3))  # [b, h, sq, dh]
    out_t = jnp.transpose(out.astype(jnp.float32), (0, 2, 1, 3))
    delta = jnp.sum(gt * out_t, axis=-1)  # [b, h, sq]

    def kv_step(dq_acc, kv):
        ki, vi, kpi, kmi = kv  # [b, ck, hkv, dh], ...
        kr = repeat_kv(ki, n_rep)
        vr = repeat_kv(vi, n_rep)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kr).astype(jnp.float32) * scale
        s = s + _mask_bias(q_pos[:, None, :], kpi[:, None, :], causal, window)
        s = jnp.where(kmi[:, None, None, :], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])  # [b, h, sq, ck]
        dv_r = jnp.einsum("bhqk,bhqd->bkhd", p, gt)  # [b, ck, h, dh]
        dp = jnp.einsum("bhqd,bkhd->bhqk", gt, vr.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale  # [b, h, sq, ck]
        dq_c = jnp.einsum("bhqk,bkhd->bqhd", ds, kr.astype(jnp.float32))
        dk_r = jnp.einsum("bhqk,bqhd->bkhd", ds, q.astype(jnp.float32))
        # fold GQA head groups back onto the kv heads
        dv_i = dv_r.reshape(b, ki.shape[1], hkv, n_rep, dh).sum(3)
        dk_i = dk_r.reshape(b, ki.shape[1], hkv, n_rep, dh).sum(3)
        return dq_acc + dq_c, (dk_i, dv_i)

    dq0 = jnp.zeros((b, sq, h, dh), jnp.float32)
    dq, (dk_chunks, dv_chunks) = jax.lax.scan(
        kv_step,
        dq0,
        (_chunked(k, ck), _chunked(v, ck), _chunked(k_pos, ck),
         _chunked(kv_mask, ck)),
    )
    sk = k.shape[1]
    dk = jnp.moveaxis(dk_chunks, 0, 1).reshape(b, sk, hkv, dh)
    dv = jnp.moveaxis(dv_chunks, 0, 1).reshape(b, sk, hkv, dh)
    f0 = lambda x: np.zeros(x.shape, jax.dtypes.float0)
    return (
        dq.astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
        f0(q_pos),
        f0(k_pos),
        f0(kv_mask),
    )


_flash.defvjp(_flash_fwd, _flash_bwd)

import numpy as np  # noqa: E402


def attention(q, k, v, q_pos, k_pos, cfg, causal=True, kv_mask=None):
    window = cfg.swa_window
    if cfg.attn_impl == "dense" or q.shape[1] == 1:
        return attention_dense(
            q, k, v, q_pos, k_pos, causal=causal, window=window, kv_mask=kv_mask
        )
    return attention_chunked(
        q,
        k,
        v,
        q_pos,
        k_pos,
        causal=causal,
        window=window,
        chunk_q=cfg.attn_chunk_q,
        chunk_kv=cfg.attn_chunk_kv,
        kv_mask=kv_mask,
    )
