from repro.models.transformer.config import MoEConfig, TransformerConfig
from repro.models.transformer import model

__all__ = ["TransformerConfig", "MoEConfig", "model"]
