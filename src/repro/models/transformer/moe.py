"""Mixture-of-Experts FFN with scatter-based (sort-free) token dispatch.

The dispatch is deliberately built on the same gather/scatter-with-combiner
primitives as the Pregel substrate (see DESIGN.md §5): token→expert routing
is a bipartite message exchange with a sum combiner on the way back.

Pipeline (per layer, tokens flattened to T = B·S):
  1. router logits [T, E] (fp32) → top-k gates (softmax over chosen k);
  2. position-in-expert via a capped running count (argsort-free cumsum on
     one-hot columns is O(T·E); we instead sort by expert id — O(T·k log) —
     which XLA lowers to an efficient key-value sort on TPU);
  3. scatter token activations into a capacity-padded expert buffer
     [E, C, D] (slots beyond capacity are dropped — standard GShard policy);
  4. per-expert SwiGLU via batched einsum [E, C, D] × [E, D, F];
  5. gather back + combine with gate weights (segment-sum by token id).

Shared experts (DeepSeekMoE) are a dense SwiGLU over all tokens, added in.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import BATCH, constrain
from repro.models.transformer.config import MoEConfig


def init_moe_params(key, d_model: int, mcfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    e, f = mcfg.n_experts, mcfg.d_ff_expert
    s = 1.0 / math.sqrt(d_model)
    params = {
        "router": (jax.random.normal(ks[0], (d_model, e)) * s).astype(jnp.float32),
        "w1": (jax.random.normal(ks[1], (e, d_model, f)) * s).astype(dtype),
        "w3": (jax.random.normal(ks[2], (e, d_model, f)) * s).astype(dtype),
        "w2": (
            jax.random.normal(ks[3], (e, f, d_model)) * (1.0 / math.sqrt(f))
        ).astype(dtype),
    }
    if mcfg.n_shared_experts:
        sf = mcfg.shared_ff
        k1, k2, k3 = jax.random.split(ks[4], 3)
        params["shared"] = {
            "w1": (jax.random.normal(k1, (d_model, sf)) * s).astype(dtype),
            "w3": (jax.random.normal(k2, (d_model, sf)) * s).astype(dtype),
            "w2": (
                jax.random.normal(k3, (sf, d_model)) * (1.0 / math.sqrt(sf))
            ).astype(dtype),
        }
    return params


def capacity(n_tokens: int, mcfg: MoEConfig) -> int:
    c = int(
        math.ceil(n_tokens * mcfg.top_k * mcfg.capacity_factor / mcfg.n_experts)
    )
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def route(
    x: jax.Array, router_w: jax.Array, mcfg: MoEConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (expert_idx [T,k], gate [T,k], aux_loss)."""
    logits = (x.astype(jnp.float32) @ router_w).astype(jnp.float32)  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = jax.lax.top_k(probs, mcfg.top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss
    density = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], mcfg.n_experts, dtype=jnp.float32),
        axis=0,
    )
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * mcfg.n_experts
    return expert_idx, gate, aux


def dispatch_indices(expert_idx: jax.Array, n_experts: int, cap: int):
    """Position of each (token, slot) within its expert, via sort.

    Returns (pos [T*k], keep [T*k]): pos < cap are the kept slots.
    """
    flat = expert_idx.reshape(-1)  # [T*k]
    tk = flat.shape[0]
    order = jnp.argsort(flat)  # stable: groups tokens by expert
    sorted_e = flat[order]
    # rank within the sorted array minus the start offset of the expert group
    counts = jnp.bincount(flat, length=n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(tk) - starts[sorted_e]
    pos = jnp.zeros((tk,), jnp.int32).at[order].set(rank.astype(jnp.int32))
    keep = pos < cap
    return pos, keep


def moe_ffn(x: jax.Array, params, mcfg: MoEConfig):
    """x: [T, D] flattened tokens → (y [T, D], aux loss).

    Under an active mesh with a ``model`` axis this routes through the
    expert-parallel shard_map dispatch (:func:`moe_ffn_ep`) — GSPMD cannot
    partition the dispatch scatter (arbitrary destination rows), so the
    scatter/gather runs *manually local* per (data, expert) shard and only
    the EP combine all-reduce crosses the wire. Without a mesh (smoke
    tests, oracle comparisons) the plain single-device path runs.
    """
    from repro.dist import sharding as shd

    mesh = shd._ACTIVE_MESH
    if mesh is not None and "model" in mesh.shape:
        n_model = mesh.shape["model"]
        daxes = tuple(
            a for a in ("pod", "data") if a in mesh.shape
        )
        n_data = 1
        for a in daxes:
            n_data *= mesh.shape[a]
        if (
            mcfg.n_experts % n_model == 0
            and x.shape[0] % n_data == 0
        ):
            return moe_ffn_ep(x, params, mcfg, mesh, daxes, n_data, n_model)
    return _moe_ffn_local(x, params, mcfg)


def _moe_ffn_local(x: jax.Array, params, mcfg: MoEConfig):
    t, d = x.shape
    e, k = mcfg.n_experts, mcfg.top_k
    cap = capacity(t, mcfg)
    expert_idx, gate, aux = route(x, params["router"], mcfg)
    pos, keep = dispatch_indices(expert_idx, e, cap)

    flat_e = expert_idx.reshape(-1)  # [T*k]
    token_id = jnp.repeat(jnp.arange(t), k)  # [T*k]
    # scatter tokens into [E, C, D] (dropped slots fall out of range)
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)  # OOR sentinel
    buf = jnp.zeros((e * cap, d), x.dtype)
    gathered = constrain(x[token_id], (BATCH, None))  # [T*k, D]
    buf = buf.at[slot].add(gathered, mode="drop")
    expert_in = constrain(buf.reshape(e, cap, d), ("model", None, None))

    # per-expert SwiGLU (batched over experts; E sharded = expert parallel)
    h = jnp.einsum("ecd,edf->ecf", expert_in, params["w1"])
    g = jnp.einsum("ecd,edf->ecf", expert_in, params["w3"])
    h = jax.nn.silu(h) * g
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w2"])
    expert_out = constrain(expert_out, ("model", None, None))

    # gather back and combine with gates (segment-sum by token)
    out_slots = expert_out.reshape(e * cap, d)
    vals = jnp.take(out_slots, jnp.minimum(slot, e * cap - 1), axis=0)
    vals = vals * (gate.reshape(-1)[:, None] * keep[:, None]).astype(x.dtype)
    vals = constrain(vals, (BATCH, None))
    y = jnp.zeros((t, d), x.dtype).at[token_id].add(vals)
    y = constrain(y, (BATCH, None))

    if "shared" in params:
        sh = params["shared"]
        hshared = jax.nn.silu(x @ sh["w1"]) * (x @ sh["w3"])
        y = y + hshared @ sh["w2"]
    return y, aux


# ---------------------------------------------------------------------------
# expert-parallel dispatch (shard_map): local scatter, EP-combine all-reduce


def moe_ffn_ep(x, params, mcfg: MoEConfig, mesh, daxes, n_data, n_model):
    """Production EP flow (GShard-style, TPU-native):

    1. **dispatch** (shard_map, fully manual): every (data, model) shard
       routes its local tokens, keeps the experts it owns (E/n_model), and
       scatters *locally* into [E_loc, C_loc, D] — zero collectives;
    2. **expert compute** (pjit): batched SwiGLU on [E(model), C(data), D];
       C stays data-sharded (it's a batch dim of the einsum), weights
       all-gather only their own model-shard slice;
    3. **combine** (shard_map): local gather from owned experts, gate-mix,
       then one psum over `model` — the EP combine all-reduce, the only
       wire traffic of the dispatch.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    t, d = x.shape
    e, k = mcfg.n_experts, mcfg.top_k
    e_loc = e // n_model
    t_loc = t // n_data
    cap_loc = capacity(t_loc, mcfg)
    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def dispatch_local(x_loc, router):
        eidx, gate, aux = route(x_loc, router, mcfg)  # [T_loc, k]
        pos, keep = dispatch_indices(eidx, e, cap_loc)
        m_idx = jax.lax.axis_index("model")
        e_local = eidx - m_idx * e_loc  # [T_loc, k]
        mine = (e_local >= 0) & (e_local < e_loc) & keep.reshape(t_loc, k)
        slot = jnp.where(
            mine, e_local * cap_loc + pos.reshape(t_loc, k), e_loc * cap_loc
        )
        buf = jnp.zeros((e_loc * cap_loc, d), x_loc.dtype)
        # one scatter per routing slot: updates stay [T_loc, D] instead of
        # materializing the k×-expanded [T_loc·k, D] gather
        for j in range(k):
            buf = buf.at[slot[:, j]].add(x_loc, mode="drop")
        aux = jax.lax.pmean(aux, daxes) if daxes else aux
        return (
            buf.reshape(e_loc, cap_loc, d),
            eidx,
            gate,
            pos,
            keep,
            aux,
        )

    buf, eidx, gate, pos, keep, aux = shard_map(
        dispatch_local,
        mesh=mesh,
        in_specs=(P(dspec, None), P(None, None)),
        out_specs=(
            P("model", dspec, None),
            P(dspec, None),
            P(dspec, None),
            P(dspec),
            P(dspec),
            P(),
        ),
        check_rep=False,
    )(x, params["router"])

    # --- expert compute (pjit; E model-sharded, C data-sharded) ----------
    h = jnp.einsum("ecd,edf->ecf", buf, params["w1"])
    g = jnp.einsum("ecd,edf->ecf", buf, params["w3"])
    h = constrain(jax.nn.silu(h) * g, ("model", BATCH, None))
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w2"])
    expert_out = constrain(expert_out, ("model", BATCH, None))

    def combine_local(eout_loc, eidx, gate, pos, keep):
        # eout_loc [E_loc, cap_loc, D]
        m_idx = jax.lax.axis_index("model")
        e_local = eidx - m_idx * e_loc  # [T_loc, k]
        mine = (e_local >= 0) & (e_local < e_loc) & keep.reshape(t_loc, k)
        slot = jnp.where(
            mine,
            e_local * cap_loc + pos.reshape(t_loc, k),
            e_loc * cap_loc - 1,
        )
        rows = eout_loc.reshape(e_loc * cap_loc, d)
        y_partial = jnp.zeros((t_loc, d), rows.dtype)
        for j in range(k):  # per-slot gather keeps peaks at [T_loc, D]
            vals = jnp.take(rows, slot[:, j], axis=0)
            w = (gate[:, j] * mine[:, j]).astype(vals.dtype)
            y_partial = y_partial + vals * w[:, None]
        return jax.lax.psum(y_partial, "model")  # EP combine

    y = shard_map(
        combine_local,
        mesh=mesh,
        in_specs=(
            P("model", dspec, None),
            P(dspec, None),
            P(dspec, None),
            P(dspec),
            P(dspec),
        ),
        out_specs=P(dspec, None),
        check_rep=False,
    )(expert_out, eidx, gate, pos, keep)

    if "shared" in params:
        sh = params["shared"]
        hshared = jax.nn.silu(x @ sh["w1"]) * (x @ sh["w3"])
        y = y + hshared @ sh["w2"]
    return y, aux
