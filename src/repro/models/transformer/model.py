"""Decoder-only LM: init, train loss, prefill, and decode-step.

Layers are stacked along a leading axis and executed with ``lax.scan``
(+ remat), keeping the HLO size O(1) in depth — essential for compiling
94-layer configs against 512 dry-run devices on one CPU.
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.dist.sharding import BATCH, constrain
from repro.models import common
from repro.models.transformer import attention as attn_mod
from repro.models.transformer import moe as moe_mod
from repro.models.transformer.config import TransformerConfig


# ---------------------------------------------------------------------------
# parameters


def init_layer(key, cfg: TransformerConfig):
    d, hd = cfg.d_model, cfg.head_dim
    h, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 8)
    s = 1.0 / math.sqrt(d)
    p: Dict[str, Any] = {
        "ln1": jnp.ones((d,), cfg.pdtype),
        "ln2": jnp.ones((d,), cfg.pdtype),
        "wq": common.dense_init(ks[0], d, h * hd, cfg.pdtype),
        "wk": common.dense_init(ks[1], d, hkv * hd, cfg.pdtype),
        "wv": common.dense_init(ks[2], d, hkv * hd, cfg.pdtype),
        "wo": common.dense_init(ks[3], h * hd, d, cfg.pdtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.pdtype)
        p["bk"] = jnp.zeros((hkv * hd,), cfg.pdtype)
        p["bv"] = jnp.zeros((hkv * hd,), cfg.pdtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), cfg.pdtype)
        p["k_norm"] = jnp.ones((hd,), cfg.pdtype)
    if cfg.moe is None:
        p["ffn"] = {
            "w1": common.dense_init(ks[4], d, cfg.d_ff, cfg.pdtype),
            "w3": common.dense_init(ks[5], d, cfg.d_ff, cfg.pdtype),
            "w2": common.dense_init(ks[6], cfg.d_ff, d, cfg.pdtype),
        }
    else:
        p["moe"] = moe_mod.init_moe_params(ks[7], d, cfg.moe, cfg.pdtype)
    return p


def init(key, cfg: TransformerConfig):
    k_embed, k_layers, k_out = jax.random.split(key, 3)
    params = {
        "embed": (
            jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(cfg.pdtype),
        "layers": common.stack_init(
            k_layers, cfg.n_layers, lambda k: init_layer(k, cfg)
        ),
        "ln_f": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = (
            jax.random.normal(k_out, (cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(cfg.pdtype)
    return params


def abstract_params(cfg: TransformerConfig):
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# forward


def _attn_block(p, x, q_pos, k_pos, cfg, k_cache=None, v_cache=None, kv_mask=None):
    """Attention sub-block. If k_cache/v_cache given (decode), attends to the
    cache; returns (out, new_k, new_v) where new_k/new_v are this call's
    K/V (for cache update / prefill cache)."""
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = constrain(q.reshape(b, s, h, hd), (BATCH, None, "model", None))
    k = constrain(k.reshape(b, s, hkv, hd), (BATCH, None, "model", None))
    v = constrain(v.reshape(b, s, hkv, hd), (BATCH, None, "model", None))
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    q = attn_mod.apply_rope(q, q_pos, cfg.rope_theta)
    k = attn_mod.apply_rope(k, q_pos, cfg.rope_theta)
    new_k, new_v = k, v
    if k_cache is not None:
        k = jnp.concatenate([k_cache, k], axis=1)
        v = jnp.concatenate([v_cache, v], axis=1)
    out = attn_mod.attention(
        q, k, v, q_pos, k_pos, cfg, causal=True, kv_mask=kv_mask
    )
    return out.reshape(b, s, h * hd) @ p["wo"], new_k, new_v


def _ffn_block(p, x, cfg):
    b, s, d = x.shape
    if cfg.moe is None:
        f = p["ffn"]
        return common.swiglu(x, f["w1"], f["w3"], f["w2"]), 0.0
    y, aux = moe_mod.moe_ffn(x.reshape(b * s, d), p["moe"], cfg.moe)
    return y.reshape(b, s, d), aux


def forward(params, tokens: jax.Array, cfg: TransformerConfig):
    """Training/prefill-style full forward. Returns (hidden [B,S,D], aux)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x = constrain(x, (BATCH, None, None))
    pos = jnp.arange(s, dtype=jnp.int32)

    def layer_fn(carry, lp):
        x, aux = carry
        # barrier: stops XLA LICM from hoisting the bf16→f32 upcast of the
        # carry out of the reverse loop (which would materialize an f32 copy
        # of the whole [L, B, S, D] remat stack — 2× activation memory)
        x = common.optimization_barrier(x)
        a, _, _ = _attn_block(lp, common.rms_norm(x, lp["ln1"]), pos, pos, cfg)
        x = constrain(x + a, (BATCH, None, None))
        f, aux_l = _ffn_block(lp, common.rms_norm(x, lp["ln2"]), cfg)
        # sequence-parallel layer boundary (Megatron SP): the remat-saved
        # carry is sharded on S over `model`, shrinking the [L,B,S,D] stack
        # 16×; GSPMD inserts the AG/RS pair around attention per layer.
        x = constrain(x + f, (BATCH, "model", None))
        return (x, aux + aux_l), None

    fn = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.asarray(0.0, jnp.float32)),
                               params["layers"], unroll=cfg.scan_unroll)
    x = common.rms_norm(x, params["ln_f"])
    return x, aux


def logits_from_hidden(params, hidden, cfg):
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("bsd,vd->bsv", hidden, table)
    return constrain(logits, (BATCH, None, "model"))  # keep vocab sharded


def loss_fn(params, batch, cfg: TransformerConfig):
    """Next-token cross-entropy; batch = {tokens [B,S], labels [B,S]}."""
    hidden, aux = forward(params, batch["tokens"], cfg)
    logits = logits_from_hidden(params, hidden, cfg)
    ce = common.softmax_cross_entropy(logits, batch["labels"])
    return ce + 0.01 * aux


# ---------------------------------------------------------------------------
# serving: prefill + decode


def cache_len(cfg: TransformerConfig, seq_len: int) -> int:
    """SWA models only retain a window of KV (ring buffer at deploy time)."""
    if cfg.swa_window is not None:
        return min(seq_len, cfg.swa_window)
    return seq_len


def init_cache(cfg: TransformerConfig, batch: int, seq_len: int, dtype=None):
    dtype = dtype or cfg.cdtype
    c = cache_len(cfg, seq_len)
    shape = (cfg.n_layers, batch, c, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(params, cache, tokens: jax.Array, cfg: TransformerConfig):
    """One decode step: tokens [B, 1] + cache → (logits [B, V], new cache).

    The cache is dense [L, B, C, Hkv, Dh]; `length` tracks the valid prefix.
    For SWA models C == window and positions wrap (ring buffer).
    """
    b = tokens.shape[0]
    c = cache["k"].shape[2]
    length = cache["length"]  # [B]
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    q_pos = length[:, None]  # true position ids [B, 1]
    slot = length % c  # ring-buffer slot [B]
    # absolute position held by each cache slot: slot i holds position p with
    # p ≡ i (mod c) and length - c ≤ p < length (ring-buffer reconstruction)
    slots = jnp.arange(c, dtype=jnp.int32)[None]  # [1, C]
    base = length[:, None] - 1 - ((length[:, None] - 1 - slots) % c)
    k_pos = jnp.where(length[:, None] > 0, base, 0)
    kv_mask = (slots < length[:, None]) | (length[:, None] >= c)

    # the concatenated KV is [cache slots..., current token]
    k_pos_full = jnp.concatenate([k_pos, q_pos], axis=1)
    kv_mask_full = jnp.concatenate([kv_mask, jnp.ones((b, 1), jnp.bool_)], axis=1)

    def layer_fn(x, lp_and_cache):
        lp, kc, vc = lp_and_cache
        a, nk, nv = _attn_block(
            lp,
            common.rms_norm(x, lp["ln1"]),
            q_pos,
            k_pos_full,
            cfg,
            k_cache=kc,
            v_cache=vc,
            kv_mask=kv_mask_full,
        )
        x = x + a
        f, _ = _ffn_block(lp, common.rms_norm(x, lp["ln2"]), cfg)
        x = x + f
        # write new K/V into the ring slot
        bidx = jnp.arange(b)
        kc = kc.at[bidx, slot].set(nk[:, 0])
        vc = vc.at[bidx, slot].set(nv[:, 0])
        return x, (kc, vc)

    def scan_body(x, layer):
        lp, kc, vc = layer
        x, (kc, vc) = layer_fn(x, (lp, kc, vc))
        return x, (kc, vc)

    x, (new_k, new_v) = jax.lax.scan(
        scan_body, x, (params["layers"], cache["k"], cache["v"]),
        unroll=cfg.scan_unroll,
    )
    x = common.rms_norm(x, params["ln_f"])
    logits = logits_from_hidden(params, x, cfg)[:, 0]
    new_cache = {"k": new_k, "v": new_v, "length": length + 1}
    return logits, new_cache


def prefill(params, tokens: jax.Array, cfg: TransformerConfig,
            capacity: int = 0, full_logits: bool = True):
    """Full-sequence prefill: returns (logits, cache).

    ``capacity`` sets the KV ring-buffer size (0 ⇒ ``cache_len(cfg, s)``).
    The ring invariant is slot == position % capacity, so decode_step can
    reconstruct absolute positions for RoPE-consistent masking.
    ``full_logits=False`` (production serving) unembeds only the final
    position — a [B,S,V] logits tensor at 32k×152k vocab is ~20 GB/device
    and is never needed for sampling.
    """
    b, s = tokens.shape
    c = capacity or cache_len(cfg, s)
    pos = jnp.arange(s, dtype=jnp.int32)
    keep = min(s, c)
    kept_pos = jnp.arange(s - keep, s, dtype=jnp.int32)
    kept_slots = kept_pos % c

    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)

    def layer_fn(x, lp):
        x = common.optimization_barrier(x)
        a, nk, nv = _attn_block(lp, common.rms_norm(x, lp["ln1"]), pos, pos, cfg)
        x = constrain(x + a, (BATCH, None, None))
        f, _ = _ffn_block(lp, common.rms_norm(x, lp["ln2"]), cfg)
        x = constrain(x + f, (BATCH, "model", None))
        # scatter the retained KVs into their ring slots; the stacked cache
        # shards its sequence dim over `model` (KV sequence parallelism)
        kc = jnp.zeros((b, c) + nk.shape[2:], nk.dtype)
        vc = jnp.zeros((b, c) + nv.shape[2:], nv.dtype)
        kc = kc.at[:, kept_slots].set(nk[:, s - keep:])
        vc = vc.at[:, kept_slots].set(nv[:, s - keep:])
        kc = constrain(kc, (BATCH, "model", None, None))
        vc = constrain(vc, (BATCH, "model", None, None))
        return x, (kc, vc)

    fn = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    x, (ks, vs) = jax.lax.scan(fn, x, params["layers"],
                               unroll=cfg.scan_unroll)
    x = common.rms_norm(x, params["ln_f"])
    if full_logits:
        logits = logits_from_hidden(params, x, cfg)
    else:
        last = constrain(x[:, -1:, :], (BATCH, None, None))
        logits = logits_from_hidden(params, last, cfg)[:, 0]
    cache = {
        "k": ks,
        "v": vs,
        "length": jnp.full((b,), s, jnp.int32),
    }
    return logits, cache


# ---------------------------------------------------------------------------
# dry-run input specs


def input_specs(cfg: TransformerConfig, shape: str, seq_len: int, batch: int):
    if shape == "train":
        return {
            "tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
            "labels": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        }
    if shape == "prefill":
        return {"tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}
    if shape == "decode":
        c = cache_len(cfg, seq_len)
        return {
            "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            "cache": {
                "k": jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, c, cfg.n_kv_heads, cfg.head_dim),
                    cfg.cdtype,
                ),
                "v": jax.ShapeDtypeStruct(
                    (cfg.n_layers, batch, c, cfg.n_kv_heads, cfg.head_dim),
                    cfg.cdtype,
                ),
                "length": jax.ShapeDtypeStruct((batch,), jnp.int32),
            },
        }
    raise ValueError(shape)
