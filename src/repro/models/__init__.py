"""Model zoo: LM transformers (dense + MoE), GNNs, recsys.

All models follow the same functional contract:

* ``init(key, cfg)``/``abstract_params(cfg)`` — parameter pytree (nested
  dicts of arrays / ShapeDtypeStructs; layer-stacked along a leading axis
  for ``lax.scan``);
* ``loss_fn(params, batch, cfg)`` — scalar loss (training);
* ``forward``/``prefill``/``decode_step`` as the family dictates;
* ``input_specs(cfg, shape)`` — ShapeDtypeStructs for the dry-run;
* sharding rules live in ``repro.dist.sharding`` keyed by param path.
"""
