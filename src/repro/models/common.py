"""Shared NN building blocks (pure-functional, no framework).

Parameters are nested dicts of jnp arrays. Initializers take an explicit key
and return the pytree; ``abstract`` variants return ShapeDtypeStructs so the
multi-pod dry-run never allocates memory.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _native_barrier_differentiates() -> bool:
    try:
        jax.eval_shape(
            lambda x: jax.jvp(jax.lax.optimization_barrier, (x,), (x,)),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        return True
    except NotImplementedError:
        return False


if _native_barrier_differentiates():
    # modern jaxlib: the primitive has full AD rules (incl. forward mode)
    optimization_barrier = jax.lax.optimization_barrier
else:
    # jaxlib < 0.4.38 defines no AD rule for the barrier primitive; it is
    # semantically the identity, so the VJP barriers the cotangent instead
    # — which also keeps the anti-LICM effect in the *backward* scan, where
    # the hoisted-upcast problem the barrier exists for shows up
    # symmetrically. (custom_vjp costs forward-mode AD, hence the gate.)
    @jax.custom_vjp
    def optimization_barrier(x: jax.Array) -> jax.Array:
        return jax.lax.optimization_barrier(x)

    def _ob_fwd(x):
        return jax.lax.optimization_barrier(x), None

    def _ob_bwd(_, g):
        return (jax.lax.optimization_barrier(g),)

    optimization_barrier.defvjp(_ob_fwd, _ob_bwd)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * gamma


def swiglu(x, w1, w3, w2):
    """SwiGLU FFN: (silu(x@w1) * (x@w3)) @ w2."""
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def gelu_mlp(x, w1, b1, w2, b2):
    return jax.nn.gelu(x @ w1 + b1) @ w2 + b2


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-mean CE; logits [..., V] (accumulated in fp32), labels [...].

    Written gather-free (iota+select instead of take_along_axis) so GSPMD
    keeps the vocab dimension sharded — a vocab gather would all-gather
    [B,S,V] logits per device.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = (
        jnp.sum(jnp.where(iota == labels[..., None], shifted, 0.0), axis=-1)
        + m[..., 0]
    )
    return jnp.mean(lse - gold)


def sigmoid_bce(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def scan_layers(layer_fn, params_stacked, x, *, remat: bool = True, unroll: int = 1):
    """Run ``layer_fn(layer_params, x) -> x`` over a layer-stacked param
    pytree with ``lax.scan`` (+ optional remat for O(1)-layers memory)."""

    fn = jax.checkpoint(layer_fn) if remat else layer_fn

    def body(carry, layer_params):
        return fn(layer_params, carry), None

    out, _ = jax.lax.scan(body, x, params_stacked, unroll=unroll)
    return out


def stack_init(key, n: int, init_fn):
    """Initialize ``n`` layers and stack leaves along axis 0."""
    keys = jax.random.split(key, n)
    layers = [init_fn(k) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)


def abstract_like(init_fn, *args, **kwargs):
    """ShapeDtypeStruct pytree of an initializer without running it."""
    return jax.eval_shape(init_fn, *args, **kwargs)


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return sum(
        int(np.prod(leaf.shape)) if leaf.shape else 1 for leaf in leaves
    )


import numpy as np  # noqa: E402  (used by count_params only)
