"""GNN configuration covering the four assigned architectures."""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    variant: str  # "sage" | "gat" | "pna" | "graphcast"
    n_layers: int
    d_hidden: int
    d_in: int
    n_out: int  # classes (classification) or output vars (regression)
    n_heads: int = 1  # gat
    aggregator: str = "mean"  # sage: mean/sum/max; gat ignores
    fanouts: Tuple[int, ...] = ()  # minibatch sampling (graphsage)
    d_edge: int = 0  # graphcast edge features
    task: str = "node_class"  # node_class | graph_class | regression
    param_dtype: str = "float32"
    compute_dtype: str = "float32"  # graphcast uses bf16 on huge graphs
    remat: bool = True  # checkpoint each layer (full-graph activations)
    # PNA
    pna_aggregators: Tuple[str, ...] = ("mean", "max", "min", "std")
    pna_scalers: Tuple[str, ...] = ("identity", "amplification", "attenuation")
    pna_delta: float = 2.5  # avg log-degree normalizer (dataset statistic)
