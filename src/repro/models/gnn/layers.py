"""GNN layers on the shared segment-op substrate (``repro.graph.ops``).

Every layer is "one algorithmic superstep" in the paper's model: gather
neighbor state along edges, segment-reduce by destination, update locally.
The same :func:`repro.graph.ops.segment_reduce` primitive backs the Palgol
codegen and (on TPU) the Pallas ``segment_reduce`` kernel.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.dist.sharding import ALL, constrain
from repro.graph import ops as gops
from repro.models.common import dense_init


def _ce(t):
    """Shard an edge-indexed tensor over every mesh axis."""
    return constrain(t, (ALL,) + (None,) * (t.ndim - 1))


def _mean(vals, dst, n, mask):
    s = gops.mp_segment_reduce(vals, dst, n, "sum", mask=mask)
    cnt = gops.mp_segment_reduce(
        jnp.ones(vals.shape[:1], vals.dtype), dst, n, "sum", mask=mask
    )
    return s / jnp.maximum(cnt[:, None], 1.0)


def init_sage_layer(key, d_in, d_out, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_self": dense_init(k1, d_in, d_out, dtype),
        "w_nbr": dense_init(k2, d_in, d_out, dtype),
        "b": jnp.zeros((d_out,), dtype),
    }


def sage_layer(p, x, src, dst, emask, n, aggregator="mean"):
    nbr_vals = _ce(gops.mp_gather(x, src))
    if aggregator == "mean":
        agg = _mean(nbr_vals, dst, n, emask)
    else:
        agg = gops.mp_segment_reduce(nbr_vals, dst, n, aggregator, mask=emask)
        if aggregator in ("min", "max"):
            agg = jnp.where(jnp.isfinite(agg), agg, 0.0)
    return jax.nn.relu(x @ p["w_self"] + agg @ p["w_nbr"] + p["b"])


def init_gat_layer(key, d_in, d_out, n_heads, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w": dense_init(k1, d_in, n_heads * d_out, dtype),
        "a_src": (jax.random.normal(k2, (n_heads, d_out)) * 0.1).astype(dtype),
        "a_dst": (jax.random.normal(k3, (n_heads, d_out)) * 0.1).astype(dtype),
    }


def gat_layer(p, x, src, dst, emask, n, n_heads, d_out, concat=True):
    h = (x @ p["w"]).reshape(n, n_heads, d_out)
    alpha_src = jnp.einsum("nhd,hd->nh", h, p["a_src"])
    alpha_dst = jnp.einsum("nhd,hd->nh", h, p["a_dst"])
    scores = _ce(jax.nn.leaky_relu(
        gops.mp_gather(alpha_src, src)
        + gops.mp_gather(alpha_dst, dst),
        negative_slope=0.2,
    ))  # [E, H]
    att = _ce(gops.mp_edge_softmax(scores, dst, n, mask=emask))
    vals = _ce(gops.mp_gather(h, src) * att[..., None])  # [E, H, D]
    out = gops.mp_segment_reduce(vals, dst, n, "sum", mask=emask)  # [N, H, D]
    if concat:
        return jax.nn.elu(out.reshape(n, n_heads * d_out))
    return jax.nn.elu(jnp.mean(out, axis=1))


def init_pna_layer(key, d_in, d_out, n_agg, n_scale, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w": dense_init(k1, d_in * (1 + n_agg * n_scale), d_out, dtype),
        "b": jnp.zeros((d_out,), dtype),
        "w_pre": dense_init(k2, d_in, d_in, dtype),
    }


def pna_layer(p, x, src, dst, emask, n, aggregators, scalers, delta):
    msg = _ce(jax.nn.relu(gops.mp_gather(x, src) @ p["w_pre"]))
    deg = gops.mp_segment_reduce(
        jnp.ones(msg.shape[:1], x.dtype), dst, n, "sum", mask=emask
    )
    aggs = []
    mean = _mean(msg, dst, n, emask)
    for a in aggregators:
        if a == "mean":
            aggs.append(mean)
        elif a == "std":
            sq = _mean(jnp.square(msg), dst, n, emask)
            aggs.append(jnp.sqrt(jnp.maximum(sq - jnp.square(mean), 0.0) + 1e-5))
        else:
            v = gops.mp_segment_reduce(msg, dst, n, a, mask=emask)
            aggs.append(jnp.where(jnp.isfinite(v), v, 0.0))
    agg = jnp.stack(aggs, axis=1)  # [N, A, D]
    logd = jnp.log1p(deg)[:, None, None]
    outs = []
    for s in scalers:
        if s == "identity":
            outs.append(agg)
        elif s == "amplification":
            outs.append(agg * (logd / delta))
        elif s == "attenuation":
            outs.append(agg * (delta / jnp.maximum(logd, 1e-3)))
    feats = jnp.concatenate(
        [x] + [o.reshape(n, -1) for o in outs], axis=-1
    )
    return jax.nn.relu(feats @ p["w"] + p["b"])



def _fused_mesh():
    return gops._mp_mesh()


def pna_layer_fused(p, x, src, dst, emask, n, aggregators, scalers, delta):
    """PNA with all aggregations in ONE shard_map region: the node state is
    replicated once per layer (instead of once per mp_* call), which is the
    peak-memory lever on 62M-edge graphs. Falls back to the composable
    version off-mesh."""
    mesh, daxes, n_data = _fused_mesh()
    if mesh is None or n_data == 1 or src.shape[0] % n_data != 0:
        return pna_layer(p, x, src, dst, emask, n, aggregators, scalers, delta)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    d = gops._dspec(daxes)

    n_loc = n // n_data

    def local(x_full, w_pre, src_l, dst_l, m_l):
        msg = jax.nn.relu(gops.gather(x_full, src_l) @ w_pre)
        # flat shard index in daxes order (matches out_spec dim-0 layout)
        flat = None
        for a in daxes:
            ia = jax.lax.axis_index(a)
            flat = ia if flat is None else flat * mesh.shape[a] + ia
        start = flat * n_loc

        def rs(v):  # sum-reductions return node-sharded via reduce-scatter
            return jax.lax.psum_scatter(v, daxes, scatter_dimension=0,
                                        tiled=True)

        def shard_slice(v):  # max/min: allreduce then keep the local shard
            return jax.lax.dynamic_slice_in_dim(v, start, n_loc, 0)

        outs = {}
        ones = jnp.ones(msg.shape[:1] + (1,), msg.dtype)
        outs["cnt"] = rs(gops.segment_reduce(ones, dst_l, n, "sum", mask=m_l))
        outs["sum"] = rs(gops.segment_reduce(msg, dst_l, n, "sum", mask=m_l))
        if "std" in aggregators:
            outs["sumsq"] = rs(
                gops.segment_reduce(jnp.square(msg), dst_l, n, "sum", mask=m_l)
            )
        if "max" in aggregators:
            outs["max"] = shard_slice(gops._diff_pminmax(
                gops.segment_reduce(msg, dst_l, n, "max", mask=m_l), daxes, True
            ))
        if "min" in aggregators:
            outs["min"] = shard_slice(gops._diff_pminmax(
                gops.segment_reduce(msg, dst_l, n, "min", mask=m_l), daxes,
                False,
            ))
        return tuple(outs[k] for k in sorted(outs))

    keys = ["cnt", "sum"]
    if "std" in aggregators:
        keys.append("sumsq")
    if "max" in aggregators:
        keys.append("max")
    if "min" in aggregators:
        keys.append("min")
    keys = sorted(keys)
    res = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(None, None), P(None, None), P(d), P(d), P(d)),
        out_specs=tuple(P(d, None) for _ in keys),
        check_rep=False,
    )(x, p["w_pre"], src, dst, emask)
    r = dict(zip(keys, res))
    cnt = jnp.maximum(r["cnt"][:, :1], 1.0)
    mean = r["sum"] / cnt
    deg = r["cnt"][:, 0]
    aggs = []
    for a in aggregators:
        if a == "mean":
            aggs.append(mean)
        elif a == "std":
            sq = r["sumsq"] / cnt
            aggs.append(jnp.sqrt(jnp.maximum(sq - jnp.square(mean), 0.0) + 1e-5))
        elif a == "max":
            aggs.append(jnp.where(jnp.isfinite(r["max"]), r["max"], 0.0))
        elif a == "min":
            aggs.append(jnp.where(jnp.isfinite(r["min"]), r["min"], 0.0))
    agg = constrain(jnp.stack(aggs, axis=1), (ALL, None, None))
    logd = jnp.log1p(deg)[:, None, None]
    outs = []
    for s in scalers:
        if s == "identity":
            outs.append(agg)
        elif s == "amplification":
            outs.append(agg * (logd / delta))
        elif s == "attenuation":
            outs.append(agg * (delta / jnp.maximum(logd, 1e-3)))
    feats = jnp.concatenate([x] + [o.reshape(n, -1) for o in outs], axis=-1)
    return jax.nn.relu(feats @ p["w"] + p["b"])


def mpnn_layer_fused(p, x, e_feat, src, dst, emask, n):
    """GraphCast block with gathers + edge MLP + aggregation fused into one
    shard_map region: one node-state replication per layer."""
    mesh, daxes, n_data = _fused_mesh()
    if mesh is None or n_data == 1 or src.shape[0] % n_data != 0:
        return mpnn_layer(p, x, e_feat, src, dst, emask, n)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    d = gops._dspec(daxes)

    def local(x_full, e_loc, w1, w2, src_l, dst_l, m_l):
        cat = jnp.concatenate(
            [gops.gather(x_full, src_l), gops.gather(x_full, dst_l), e_loc],
            axis=-1,
        )
        e_new = jax.nn.silu(cat @ w1) @ w2 + e_loc
        # reduce-scatter: each device keeps only its node shard of the
        # aggregate — no replicated [N, D] buffer ever materializes
        agg = jax.lax.psum_scatter(
            gops.segment_reduce(e_new, dst_l, n, "sum", mask=m_l),
            daxes, scatter_dimension=0, tiled=True,
        )
        return e_new, agg

    e_new, agg = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(None, None), P(d, None), P(None, None), P(None, None),
            P(d), P(d), P(d),
        ),
        out_specs=(P(d, None), P(d, None)),
        check_rep=False,
    )(x, e_feat, p["edge_w1"], p["edge_w2"], src, dst, emask)
    x_new = (
        jax.nn.silu(jnp.concatenate([x, agg], axis=-1) @ p["node_w1"])
        @ p["node_w2"]
        + x
    )
    return x_new, e_new


def init_mpnn_layer(key, d_node, d_edge, dtype):
    """GraphCast-style interaction-network block (edge+node MLPs)."""
    ks = jax.random.split(key, 4)
    d_cat = 2 * d_node + d_edge
    return {
        "edge_w1": dense_init(ks[0], d_cat, d_edge, dtype),
        "edge_w2": dense_init(ks[1], d_edge, d_edge, dtype),
        "node_w1": dense_init(ks[2], d_node + d_edge, d_node, dtype),
        "node_w2": dense_init(ks[3], d_node, d_node, dtype),
    }


def mpnn_layer(p, x, e_feat, src, dst, emask, n):
    """x: [N, Dn]; e_feat: [E, De] → (x', e') with residuals (GraphCast)."""
    cat = _ce(jnp.concatenate(
        [
            gops.mp_gather(x, src),
            gops.mp_gather(x, dst),
            e_feat,
        ],
        axis=-1,
    ))
    e_new = _ce(jax.nn.silu(cat @ p["edge_w1"]) @ p["edge_w2"] + e_feat)
    agg = gops.mp_segment_reduce(e_new, dst, n, "sum", mask=emask)
    x_new = (
        jax.nn.silu(jnp.concatenate([x, agg], axis=-1) @ p["node_w1"])
        @ p["node_w2"]
        + x
    )
    return x_new, e_new
