from repro.models.gnn.config import GNNConfig
from repro.models.gnn import models

__all__ = ["GNNConfig", "models"]
