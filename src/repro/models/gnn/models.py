"""GNN models: init, loss, train/serve steps for all four assigned archs.

Batch contract (full-graph modes):
    {"x": [N, Din], "src": [E], "dst": [E], "emask": [E],
     "labels": [N] or [N, n_out], "lmask": [N]}
Batched small graphs (``molecule``) use the disjoint-union layout with a
``graph_id`` [N] vector and graph-level labels [B].
Sampled minibatch (``minibatch_lg``) uses padded sampler blocks:
    {"seed_x": [B, Din], "hop0_x": [B*f0, Din], "hop0_mask": [B, f0],
     "hop1_x": [B*f0*f1, Din], "hop1_mask": [B*f0, f1], "labels": [B]}
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.dist.sharding import ALL, constrain
from repro.graph import ops as gops
from repro.models import common
from repro.models.common import dense_init
from repro.models.gnn import layers as L
from repro.models.gnn.config import GNNConfig


# ---------------------------------------------------------------------------
# init


def init(key, cfg: GNNConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, cfg.n_layers + 3)
    p: Dict[str, Any] = {"layers": []}
    d = cfg.d_hidden
    if cfg.variant == "sage":
        dims = [cfg.d_in] + [d] * cfg.n_layers
        p["layers"] = [
            L.init_sage_layer(ks[i], dims[i], dims[i + 1], dtype)
            for i in range(cfg.n_layers)
        ]
    elif cfg.variant == "gat":
        dims = [cfg.d_in] + [d * cfg.n_heads] * cfg.n_layers
        p["layers"] = [
            L.init_gat_layer(ks[i], dims[i], d, cfg.n_heads, dtype)
            for i in range(cfg.n_layers)
        ]
    elif cfg.variant == "pna":
        na, nsc = len(cfg.pna_aggregators), len(cfg.pna_scalers)
        # first layer maps d_in -> d; the uniform tail is stacked for scan
        p["layer0"] = L.init_pna_layer(ks[0], cfg.d_in, d, na, nsc, dtype)
        if cfg.n_layers > 1:
            p["layers"] = common.stack_init(
                ks[1], cfg.n_layers - 1,
                lambda k: L.init_pna_layer(k, d, d, na, nsc, dtype),
            )
        else:
            p["layers"] = None
    elif cfg.variant == "graphcast":
        de = max(cfg.d_edge, d)
        p["encode_node"] = dense_init(ks[-3], cfg.d_in, d, dtype)
        p["encode_edge"] = dense_init(ks[-2], 1, de, dtype)  # from edge weight
        # identical processor blocks: stacked + lax.scan (buffer reuse
        # across layers — unrolled layers keep 16 sets of temps alive)
        p["layers"] = common.stack_init(
            ks[0], cfg.n_layers, lambda k: L.init_mpnn_layer(k, d, de, dtype)
        )
    else:
        raise ValueError(cfg.variant)
    d_final = d * cfg.n_heads if cfg.variant == "gat" else d
    p["head"] = dense_init(ks[-1], d_final, cfg.n_out, dtype)
    return p


def abstract_params(cfg: GNNConfig):
    return jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))


# ---------------------------------------------------------------------------
# full-graph forward


def forward(params, batch, cfg: GNNConfig):
    cdt = jnp.dtype(cfg.compute_dtype)
    x = batch["x"].astype(cdt)
    src, dst, emask = batch["src"], batch["dst"], batch["emask"]
    n = x.shape[0]

    def _c(t):  # shard node/edge activations over every mesh axis
        return constrain(t, (ALL,) + (None,) * (t.ndim - 1))

    x = _c(x)
    maybe_ckpt = jax.checkpoint if cfg.remat else (lambda f: f)
    if cfg.variant == "graphcast":
        cast_params = jax.tree_util.tree_map(
            lambda p: p.astype(cdt) if p.dtype == jnp.float32 else p, params
        )
        h = _c(jax.nn.silu(x @ cast_params["encode_node"]))
        w = batch.get("ew", jnp.ones(src.shape, x.dtype)).astype(cdt)
        e = _c(jax.nn.silu(w[:, None] @ cast_params["encode_edge"]))  # [E, De]

        def gc_body(carry, lp):
            h, e = carry
            h = common.optimization_barrier(h)
            h, e = L.mpnn_layer_fused(lp, h, e, src, dst, emask, n)
            return (_c(h), _c(e)), None

        body = maybe_ckpt(gc_body)
        (h, e), _ = jax.lax.scan(body, (h, e), cast_params["layers"])
        return (h @ cast_params["head"]).astype(jnp.float32)

    if cfg.variant == "pna":
        # cast params to the compute dtype (else bf16 x promotes back to f32)
        cparams = jax.tree_util.tree_map(
            lambda p: p.astype(cdt) if p.dtype == jnp.float32 else p, params
        )

        def pna_apply(lp, h):
            return _c(L.pna_layer_fused(
                lp, h, src, dst, emask, n,
                cfg.pna_aggregators, cfg.pna_scalers, cfg.pna_delta,
            ))

        h = maybe_ckpt(pna_apply)(cparams["layer0"], x)
        if cparams.get("layers") is not None:
            def pna_body(h, lp):
                h = common.optimization_barrier(h)
                return maybe_ckpt(pna_apply)(lp, h), None

            h, _ = jax.lax.scan(pna_body, h, cparams["layers"])
        return (h @ cparams["head"]).astype(jnp.float32)

    def one_layer(lp, h):
        if cfg.variant == "sage":
            h = L.sage_layer(lp, h, src, dst, emask, n, cfg.aggregator)
        elif cfg.variant == "gat":
            h = L.gat_layer(lp, h, src, dst, emask, n, cfg.n_heads,
                            cfg.d_hidden)
        return _c(h)

    one_layer = maybe_ckpt(one_layer)
    h = x
    for lp in params["layers"]:
        h = one_layer(lp, h)
    return (h @ params["head"]).astype(jnp.float32)


def loss_fn(params, batch, cfg: GNNConfig):
    out = forward(params, batch, cfg)
    if cfg.task == "regression":
        if "graph_id" in batch:
            # batched small graphs: per-graph property regression
            gid = batch["graph_id"]
            n_graphs = batch["labels"].shape[0]
            pooled = gops.segment_reduce(out, gid, n_graphs, "sum")
            cnt = gops.segment_reduce(
                jnp.ones(out.shape[:1], out.dtype), gid, n_graphs, "sum"
            )
            pred = pooled / jnp.maximum(cnt[:, None], 1.0)
            return jnp.mean(jnp.square((pred - batch["labels"]).astype(jnp.float32)))
        err = (out - batch["labels"]).astype(jnp.float32)
        m = batch.get("lmask")
        if m is not None:
            err = err * m[:, None]
            denom = jnp.maximum(jnp.sum(m), 1.0) * out.shape[-1]
            return jnp.sum(jnp.square(err)) / denom
        return jnp.mean(jnp.square(err))
    if cfg.task == "graph_class":
        # disjoint-union batching: mean-pool nodes per graph
        gid = batch["graph_id"]
        n_graphs = batch["labels"].shape[0]
        pooled = gops.segment_reduce(out, gid, n_graphs, "sum")
        cnt = gops.segment_reduce(
            jnp.ones(out.shape[:1], out.dtype), gid, n_graphs, "sum"
        )
        logits = pooled / jnp.maximum(cnt[:, None], 1.0)
        return common.softmax_cross_entropy(logits, batch["labels"])
    # node classification with a labeled-node mask
    logits = out.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    per_node = lse - gold
    m = batch.get("lmask")
    if m is not None:
        per_node = per_node * m
        return jnp.sum(per_node) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(per_node)


# ---------------------------------------------------------------------------
# sampled-minibatch SAGE (GraphSAGE's native training mode)


def sage_minibatch_forward(params, batch, cfg: GNNConfig):
    """Two-hop sampled forward with padded blocks (fanouts f0, f1)."""
    assert cfg.variant == "sage" and len(cfg.fanouts) == 2
    f0, f1 = cfg.fanouts
    seed_x = batch["seed_x"]  # [B, Din]
    hop0_x = batch["hop0_x"]  # [B*f0, Din]
    hop1_x = batch["hop1_x"]  # [B*f0*f1, Din]
    m0 = batch["hop0_mask"]  # [B, f0]
    m1 = batch["hop1_mask"]  # [B*f0, f1]
    b = seed_x.shape[0]
    l1, l2 = params["layers"]

    def masked_mean(vals, mask):
        w = mask[..., None].astype(vals.dtype)
        return jnp.sum(vals * w, axis=-2) / jnp.maximum(
            jnp.sum(w, axis=-2), 1.0
        )

    # layer 1 at hop-0 nodes: aggregate their sampled hop-1 neighbors
    nbr1 = masked_mean(hop1_x.reshape(b * f0, f1, -1), m1)
    h0 = jax.nn.relu(hop0_x @ l1["w_self"] + nbr1 @ l1["w_nbr"] + l1["b"])
    # layer 1 at seeds (self transform with their own neighbors = hop0 raw)
    nbr_seed = masked_mean(hop0_x.reshape(b, f0, -1), m0)
    h_seed = jax.nn.relu(seed_x @ l1["w_self"] + nbr_seed @ l1["w_nbr"] + l1["b"])
    # layer 2 at seeds: aggregate hop-0 hidden states
    nbr2 = masked_mean(h0.reshape(b, f0, -1), m0)
    h = jax.nn.relu(h_seed @ l2["w_self"] + nbr2 @ l2["w_nbr"] + l2["b"])
    return h @ params["head"]


def sage_minibatch_loss(params, batch, cfg: GNNConfig):
    logits = sage_minibatch_forward(params, batch, cfg)
    return common.softmax_cross_entropy(logits, batch["labels"])


# ---------------------------------------------------------------------------
# dry-run input specs


def input_specs(cfg: GNNConfig, shape_kind: str, **dims):
    f32, i32 = jnp.float32, jnp.int32
    if shape_kind == "full_graph":
        n, e = dims["n_nodes"], dims["n_edges"]
        d = dims.get("d_feat", cfg.d_in)
        spec = {
            "x": jax.ShapeDtypeStruct((n, d), f32),
            "src": jax.ShapeDtypeStruct((e,), i32),
            "dst": jax.ShapeDtypeStruct((e,), i32),
            "emask": jax.ShapeDtypeStruct((e,), jnp.bool_),
        }
        if cfg.task == "regression":
            spec["labels"] = jax.ShapeDtypeStruct((n, cfg.n_out), f32)
        else:
            spec["labels"] = jax.ShapeDtypeStruct((n,), i32)
        spec["lmask"] = jax.ShapeDtypeStruct((n,), f32)
        return spec
    if shape_kind == "minibatch":
        b = dims["batch_nodes"]
        f0, f1 = cfg.fanouts
        d = dims.get("d_feat", cfg.d_in)
        return {
            "seed_x": jax.ShapeDtypeStruct((b, d), f32),
            "hop0_x": jax.ShapeDtypeStruct((b * f0, d), f32),
            "hop0_mask": jax.ShapeDtypeStruct((b, f0), jnp.bool_),
            "hop1_x": jax.ShapeDtypeStruct((b * f0 * f1, d), f32),
            "hop1_mask": jax.ShapeDtypeStruct((b * f0, f1), jnp.bool_),
            "labels": jax.ShapeDtypeStruct((b,), i32),
        }
    if shape_kind == "batched_graphs":
        b, n, e = dims["batch"], dims["n_nodes"], dims["n_edges"]
        d = dims.get("d_feat", cfg.d_in)
        labels = (
            jax.ShapeDtypeStruct((b, cfg.n_out), f32)
            if cfg.task == "regression"
            else jax.ShapeDtypeStruct((b,), i32)
        )
        return {
            "x": jax.ShapeDtypeStruct((b * n, d), f32),
            "src": jax.ShapeDtypeStruct((b * e,), i32),
            "dst": jax.ShapeDtypeStruct((b * e,), i32),
            "emask": jax.ShapeDtypeStruct((b * e,), jnp.bool_),
            "graph_id": jax.ShapeDtypeStruct((b * n,), i32),
            "labels": labels,
        }
    raise ValueError(shape_kind)
