"""Synthetic graph generators (host-side, deterministic by seed).

Real deployments load partitioned edge lists from distributed storage; these
generators stand in for the loader in tests/benchmarks and reproduce the
qualitative degree distributions of the paper's datasets (power-law social
graphs) at laptop scale.
"""

from __future__ import annotations

import numpy as np

from repro.graph.structure import Graph, from_edge_list, symmetrize


def chain(n: int, weighted: bool = False, seed: int = 0) -> Graph:
    """Path graph 0→1→…→n-1 (directed)."""
    src = np.arange(n - 1, dtype=np.int32)
    dst = src + 1
    w = None
    if weighted:
        rng = np.random.default_rng(seed)
        w = rng.uniform(0.5, 2.0, size=src.shape).astype(np.float32)
    return from_edge_list(src, dst, n, w)


def cycle(n: int) -> Graph:
    src = np.arange(n, dtype=np.int32)
    dst = (src + 1) % n
    return from_edge_list(src, dst, n)


def star(n: int) -> Graph:
    """Undirected star: hub 0 connected to 1..n-1."""
    src = np.zeros(n - 1, dtype=np.int32)
    dst = np.arange(1, n, dtype=np.int32)
    s, d, w = symmetrize(src, dst)
    return from_edge_list(s, d, n, w)


def grid2d(rows: int, cols: int) -> Graph:
    """Undirected 2D grid."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()])
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()])
    src = np.concatenate([right[0], down[0]])
    dst = np.concatenate([right[1], down[1]])
    s, d, w = symmetrize(src, dst)
    return from_edge_list(s, d, rows * cols, w)


def erdos_renyi(
    n: int,
    avg_degree: float = 8.0,
    directed: bool = False,
    weighted: bool = False,
    seed: int = 0,
) -> Graph:
    """G(n, m) random graph with m ≈ n*avg_degree(/2 if undirected)."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree) if directed else int(n * avg_degree / 2)
    src = rng.integers(0, n, size=m, dtype=np.int32)
    dst = rng.integers(0, n, size=m, dtype=np.int32)
    keep = src != dst  # no self loops
    src, dst = src[keep], dst[keep]
    w = rng.uniform(0.1, 10.0, size=src.shape).astype(np.float32) if weighted else None
    if directed:
        return from_edge_list(src, dst, n, w)
    s, d, w2 = symmetrize(src, dst, w)
    return from_edge_list(s, d, n, w2)


def rmat(
    n_log2: int,
    avg_degree: float = 16.0,
    directed: bool = True,
    weighted: bool = False,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
) -> Graph:
    """R-MAT power-law generator (Graph500 parameters by default).

    Matches the skewed degree distributions of LJ/Facebook/Wikipedia used in
    the paper's evaluation.
    """
    n = 1 << n_log2
    m = int(n * avg_degree)
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for level in range(n_log2):
        r = rng.random(m)
        # quadrant probabilities a,b,c,d
        go_right = (r >= a) & (r < a + b) | (r >= a + b + c)
        go_down = r >= a + b
        src |= go_down.astype(np.int64) << level
        dst |= go_right.astype(np.int64) << level
    keep = src != dst
    src, dst = src[keep].astype(np.int32), dst[keep].astype(np.int32)
    w = rng.uniform(0.1, 10.0, size=src.shape).astype(np.float32) if weighted else None
    if directed:
        return from_edge_list(src, dst, n, w)
    s, d, w2 = symmetrize(src, dst, w)
    return from_edge_list(s, d, n, w2)


def random_bipartite(n_left: int, n_right: int, avg_degree: float = 4.0, seed: int = 0):
    """Undirected bipartite graph; returns (graph, side) where side[v]∈{0,1}."""
    rng = np.random.default_rng(seed)
    m = int((n_left + n_right) * avg_degree / 2)
    left = rng.integers(0, n_left, size=m, dtype=np.int32)
    right = rng.integers(0, n_right, size=m, dtype=np.int32) + n_left
    s, d, w = symmetrize(left, right)
    n = n_left + n_right
    side = np.zeros(n, dtype=np.int32)
    side[n_left:] = 1
    return from_edge_list(s, d, n, w), side


def forest_pointers(n: int, n_trees: int = 4, seed: int = 0) -> np.ndarray:
    """Random parent-pointer forest (for chain-access tests): D[u] = parent."""
    rng = np.random.default_rng(seed)
    parent = np.arange(n, dtype=np.int32)
    roots = rng.choice(n, size=n_trees, replace=False)
    for u in range(n):
        if u in roots:
            continue
        # point to a random smaller-indexed vertex to keep it acyclic-ish; or a root
        parent[u] = (
            rng.choice(roots) if rng.random() < 0.3 else rng.integers(0, max(u, 1))
        )
    return parent
