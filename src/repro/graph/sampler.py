"""k-hop uniform neighbor sampler (GraphSAGE-style minibatch training).

``minibatch_lg`` requires a real sampler: given seed nodes and per-hop
fanouts, draw uniform neighbor samples from the CSR adjacency and emit a
*padded, statically-shaped* sampled block per hop — the shape contract the
pjit'd train step is lowered against.

Zero-degree nodes sample the sentinel (== n_vertices) with mask False; the
model's segment ops drop those rows. Sampling runs in JAX (jit-able, runs on
host CPU in the input pipeline at deployment).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SampledBlock:
    """One hop of sampled neighborhood.

    ``nodes``:   i32[B]            destination nodes of this hop
    ``neighbors``: i32[B, fanout]  sampled in-neighbors (sentinel-padded)
    ``mask``:    bool[B, fanout]
    """

    nodes: jax.Array
    neighbors: jax.Array
    mask: jax.Array


@dataclasses.dataclass(frozen=True)
class CSR:
    """Host-built CSR adjacency (in-neighbors)."""

    indptr: jax.Array  # i32[N+1]
    indices: jax.Array  # i32[nnz]
    n_vertices: int

    @staticmethod
    def from_graph(graph) -> "CSR":
        dst = np.asarray(graph.dst)
        src = np.asarray(graph.src)
        m = np.asarray(graph.edge_mask)
        dst, src = dst[m], src[m]
        order = np.argsort(dst, kind="stable")
        dst, src = dst[order], src[order]
        counts = np.bincount(dst, minlength=graph.n_vertices)
        indptr = np.zeros(graph.n_vertices + 1, np.int32)
        np.cumsum(counts, out=indptr[1:])
        return CSR(jnp.asarray(indptr), jnp.asarray(src.astype(np.int32)),
                   graph.n_vertices)


def sample_neighbors(csr: CSR, nodes: jax.Array, fanout: int, key) -> SampledBlock:
    """Uniform-with-replacement sample of ``fanout`` in-neighbors per node."""
    start = csr.indptr[nodes]
    degree = csr.indptr[nodes + 1] - start
    r = jax.random.randint(
        key, (nodes.shape[0], fanout), 0, jnp.maximum(degree, 1)[:, None]
    )
    idx = start[:, None] + r
    neighbors = jnp.take(csr.indices, idx, mode="clip")
    mask = degree[:, None] > 0
    mask = jnp.broadcast_to(mask, neighbors.shape)
    neighbors = jnp.where(mask, neighbors, csr.n_vertices)
    return SampledBlock(nodes=nodes, neighbors=neighbors, mask=mask)


def sample_khop(csr: CSR, seeds: jax.Array, fanouts: Sequence[int], key):
    """Multi-hop sampling: returns one SampledBlock per hop, innermost last.

    Hop ``i`` samples ``fanouts[i]`` neighbors for every frontier node; the
    next frontier is the flattened neighbor set (with replacement — standard
    GraphSAGE). Output shapes are fully static:
      hop0: nodes [B],      neighbors [B, f0]
      hop1: nodes [B*f0],   neighbors [B*f0, f1]
      ...
    """
    blocks = []
    frontier = seeds
    for i, f in enumerate(fanouts):
        key, sub = jax.random.split(key)
        # clamp sentinel frontier entries into range for indptr lookup
        safe = jnp.minimum(frontier, csr.n_vertices - 1)
        blk = sample_neighbors(csr, safe, f, sub)
        # frontier rows that were sentinels must not contribute: kill mask
        alive = (frontier < csr.n_vertices)[:, None]
        blk = SampledBlock(
            nodes=frontier,
            neighbors=jnp.where(alive, blk.neighbors, csr.n_vertices),
            mask=blk.mask & alive,
        )
        blocks.append(blk)
        frontier = blk.neighbors.reshape(-1)
    return blocks


def sampled_input_shapes(batch_nodes: int, fanouts: Sequence[int], d_feat: int):
    """ShapeDtypeStructs for a sampled minibatch (used by the dry-run)."""
    shapes = {}
    b = batch_nodes
    shapes["seed_feats"] = jax.ShapeDtypeStruct((b, d_feat), jnp.float32)
    for i, f in enumerate(fanouts):
        shapes[f"hop{i}_feats"] = jax.ShapeDtypeStruct((b * f, d_feat), jnp.float32)
        shapes[f"hop{i}_mask"] = jax.ShapeDtypeStruct((b, f), jnp.bool_)
        b = b * f
    return shapes
