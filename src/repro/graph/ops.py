"""Segment/gather/scatter primitives — the message-passing substrate.

These wrap ``jax.ops.segment_*`` and indexed updates with the combiner
semantics Palgol requires (accumulative-only remote writes). Out-of-range
indices (the padding sentinel) are *dropped*, matching Pregel's "no message"
semantics.

JAX has no native EmbeddingBag / CSR sparse; per the assignment, message
passing over an edge-index → node scatter IS part of the system and lives
here. The Pallas ``segment_reduce`` kernel (``repro.kernels``) is a drop-in
replacement for :func:`segment_reduce` on TPU hot paths.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

# identity element per combiner, keyed by op name
COMBINE_IDENTITY = {
    "sum": 0.0,
    "min": jnp.inf,
    "max": -jnp.inf,
    "prod": 1.0,
    "and": True,
    "or": False,
}


def _identity_for(op: str, dtype) -> jax.Array:
    ident = COMBINE_IDENTITY[op]
    if jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        ident = {"sum": 0, "min": info.max, "max": info.min, "prod": 1}[op]
    if dtype == jnp.bool_:
        ident = {"and": True, "or": False, "sum": False, "max": False, "min": True}[op]
    return jnp.asarray(ident, dtype=dtype)


#: elementwise combiner application — the single source for every site that
#: folds two already-reduced values (remote-write deltas, cross-shard
#: partials); keep in sync with COMBINE_IDENTITY above
COMBINE_FN = {
    "sum": jnp.add,
    "prod": jnp.multiply,
    "min": jnp.minimum,
    "max": jnp.maximum,
    "or": jnp.logical_or,
    "and": jnp.logical_and,
}


def combine(op: str, a: jax.Array, b: jax.Array) -> jax.Array:
    """Elementwise ``a op b`` for a Palgol combiner."""
    if op not in COMBINE_FN:
        raise ValueError(f"unknown combiner {op!r}")
    return COMBINE_FN[op](a, b)


def combine_along_axis(op: str, arr: jax.Array, axis: int) -> jax.Array:
    """Reduce one array axis with a Palgol combiner."""
    reducers = {
        "sum": jnp.sum,
        "prod": jnp.prod,
        "min": jnp.min,
        "max": jnp.max,
        "or": jnp.any,
        "and": jnp.all,
    }
    if op not in reducers:
        raise ValueError(f"unknown combiner {op!r}")
    return reducers[op](arr, axis=axis)


def segment_reduce(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
    indices_are_sorted: bool = False,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Reduce ``values`` by ``segment_ids`` with combiner ``op``.

    Unreduced segments receive the combiner identity (matching Palgol's list
    comprehension over an empty neighbor list, e.g. ``minimum [] = inf``).
    """
    if mask is not None:
        ident = _identity_for(op, values.dtype)
        mshape = mask.shape + (1,) * (values.ndim - mask.ndim)
        values = jnp.where(mask.reshape(mshape), values, ident)
    kwargs = dict(
        num_segments=num_segments,
        indices_are_sorted=indices_are_sorted,
    )
    if op == "sum":
        return jax.ops.segment_sum(values, segment_ids, **kwargs)
    if op == "prod":
        return jax.ops.segment_prod(values, segment_ids, **kwargs)
    if op == "min":
        out = jax.ops.segment_min(values, segment_ids, **kwargs)
        # segment_min fills empty segments with +max of dtype already; but for
        # float we want +inf explicitly
        return out
    if op == "max":
        return jax.ops.segment_max(values, segment_ids, **kwargs)
    if op == "or":
        asint = jax.ops.segment_max(values.astype(jnp.int32), segment_ids, **kwargs)
        # empty segments reduce to INT_MIN; identity of `or` is False
        return jnp.maximum(asint, 0).astype(jnp.bool_)
    if op == "and":
        asint = jax.ops.segment_min(values.astype(jnp.int32), segment_ids, **kwargs)
        # empty segments reduce to INT_MAX; identity of `and` is True
        return jnp.minimum(asint, 1).astype(jnp.bool_)
    raise ValueError(f"unknown combiner {op!r}")


def gather(field: jax.Array, idx: jax.Array, fill=None) -> jax.Array:
    """``field[idx]`` with out-of-range indices reading a fill value.

    This is the dense-runtime realization of a Palgol remote *read*: on a
    sharded field, XLA lowers it to the gather collective schedule chosen by
    the partitioner. The padding sentinel (== n_vertices) reads ``fill``.
    """
    if fill is None:
        return jnp.take(field, idx, axis=0, mode="clip")
    # fill_value must be a static (hashable) scalar, not a traced array
    import numpy as np

    fill_scalar = np.asarray(fill, np.dtype(field.dtype)).item()
    return jnp.take(field, idx, axis=0, mode="fill", fill_value=fill_scalar)


def scatter_combine(
    buffer: jax.Array,
    idx: jax.Array,
    values: jax.Array,
    op: str = "sum",
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Apply accumulative remote writes: ``buffer[idx] op= values``.

    Out-of-range indices are dropped (``mode="drop"``), which both implements
    Pregel's "message to nobody" for padding rows and makes halted-vertex
    masking cheap (redirect idx to the sentinel).
    """
    if mask is not None:
        idx = jnp.where(mask, idx, buffer.shape[0])  # out-of-range => dropped
    at = buffer.at[idx]
    if op == "sum":
        return at.add(values, mode="drop")
    if op == "min":
        return at.min(values, mode="drop")
    if op == "max":
        return at.max(values, mode="drop")
    if op == "prod":
        return at.mul(values, mode="drop")
    if op == "or":
        return (
            buffer.astype(jnp.int32)
            .at[idx]
            .max(values.astype(jnp.int32), mode="drop")
            .astype(buffer.dtype)
        )
    if op == "and":
        return (
            buffer.astype(jnp.int32)
            .at[idx]
            .min(values.astype(jnp.int32), mode="drop")
            .astype(buffer.dtype)
        )
    raise ValueError(f"unknown combiner {op!r}")


def edge_softmax(
    scores: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    mask: Optional[jax.Array] = None,
    indices_are_sorted: bool = False,
) -> jax.Array:
    """Numerically-stable softmax over edges grouped by destination (GAT)."""
    if mask is not None:
        mshape = mask.shape + (1,) * (scores.ndim - mask.ndim)
        scores = jnp.where(mask.reshape(mshape), scores, -jnp.inf)
    seg_max = segment_reduce(
        scores, segment_ids, num_segments, "max", indices_are_sorted
    )
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(scores - seg_max[segment_ids])
    if mask is not None:
        ex = jnp.where(mask.reshape(mshape), ex, 0.0)
    denom = segment_reduce(ex, segment_ids, num_segments, "sum", indices_are_sorted)
    return ex / jnp.maximum(denom[segment_ids], 1e-16)


# ---------------------------------------------------------------------------
# mesh-aware message passing (shard_map): GSPMD cannot partition the
# arbitrary-destination scatters/gathers of graph aggregation (it replicates
# the [E, D] update tensors — hundreds of GB on ogb_products). Under an
# active mesh these wrappers run the gather/scatter *locally* per edge shard
# with replicated node state, and reduce partials with one collective:
#
#   mp_gather          node[N,D] (replicated) × idx[E](sharded) → edge-local
#   mp_segment_reduce  edge-local values → local partial [N,D] → psum/pmax
#
# This is vertex-cut partitioning with replicated vertex state — the same
# scheme PowerGraph-style systems use for power-law graphs (DESIGN.md §2).


def _mp_mesh():
    from repro.dist import sharding as shd

    mesh = shd._ACTIVE_MESH
    if mesh is None:
        return None, (), 1
    # GNN message passing flattens the WHOLE mesh: edges are the only large
    # dimension, so 1-D partitioning over all chips maximizes headroom
    daxes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    n_data = 1
    for a in daxes:
        n_data *= mesh.shape[a]
    return mesh, daxes, n_data


def _dspec(daxes):
    return daxes if len(daxes) > 1 else (daxes[0] if daxes else None)


def _pad_rows(x: jax.Array, n_rows: int, fill) -> jax.Array:
    """Pad the leading dim up to ``n_rows`` with a constant."""
    pad = n_rows - x.shape[0]
    if pad == 0:
        return x
    widths = ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return jnp.pad(x, widths, constant_values=fill)


def mp_gather(field: jax.Array, idx: jax.Array, fill=None) -> jax.Array:
    """Edge-sharded gather of (replicated) node state.

    An edge count the mesh does not divide is padded up with masked
    sentinel rows (and the result sliced back) — the mesh path must never
    silently fall back to the single-device gather just because ``E`` is
    odd (that fallback replicates the ``[E, D]`` tensors GSPMD cannot
    partition, the exact failure this wrapper exists to avoid).
    """
    mesh, daxes, n_data = _mp_mesh()
    if mesh is None or n_data == 1:
        return gather(field, idx, fill)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    e = idx.shape[0]
    e_pad = -(-e // n_data) * n_data
    idx_p = _pad_rows(idx, e_pad, 0)  # pad rows gather row 0, sliced off

    d = _dspec(daxes)

    def local(f, i):
        return gather(f, i, fill)

    out_ndim = field.ndim - 1 + idx.ndim
    out = shard_map(
        local,
        mesh=mesh,
        in_specs=(P(*(None,) * field.ndim), P(d)),
        out_specs=P(d, *(None,) * (out_ndim - 1)),
        check_rep=False,
    )(field, idx_p)
    return out[:e] if e_pad != e else out


def _diff_pminmax(part: jax.Array, daxes, is_max: bool) -> jax.Array:
    """Differentiable cross-shard max/min: pmax/pmin have no VJP, so route
    the cotangent to the shards attaining the extremum (split across ties),
    matching jnp.max's subgradient convention."""

    @jax.custom_vjp
    def f(x):
        return jax.lax.pmax(x, daxes) if is_max else jax.lax.pmin(x, daxes)

    def fwd(x):
        m = f(x)
        return m, (x, m)

    def bwd(res, g):
        x, m = res
        hit = (x == m).astype(g.dtype)
        cnt = jnp.maximum(jax.lax.psum(hit, daxes), 1.0)
        return (g * hit / cnt,)

    f.defvjp(fwd, bwd)
    return f(part)


def mp_segment_reduce(
    values: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    op: str = "sum",
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Edge-sharded segment reduction → replicated node result.

    Odd edge counts are padded to mesh divisibility with masked sentinel
    rows (``segment_id = num_segments`` is dropped by the scatter) instead
    of abandoning the mesh path.
    """
    mesh, daxes, n_data = _mp_mesh()
    if mesh is None or n_data == 1:
        return segment_reduce(values, segment_ids, num_segments, op, mask=mask)
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    d = _dspec(daxes)
    if mask is None:
        mask = jnp.ones(values.shape[:1], jnp.bool_)
    e = values.shape[0]
    e_pad = -(-e // n_data) * n_data
    if e_pad != e:
        values = _pad_rows(values, e_pad, 0)
        segment_ids = _pad_rows(segment_ids, e_pad, num_segments)
        mask = _pad_rows(mask, e_pad, False)

    def local(v, s, m):
        part = segment_reduce(v, s, num_segments, op, mask=m)
        if op in ("sum", "prod"):
            return jax.lax.psum(part, daxes)
        if op == "max":
            return _diff_pminmax(part, daxes, True)
        if op == "min":
            return _diff_pminmax(part, daxes, False)
        if op == "or":
            return jax.lax.pmax(part.astype(jnp.int32), daxes).astype(jnp.bool_)
        if op == "and":
            return jax.lax.pmin(part.astype(jnp.int32), daxes).astype(jnp.bool_)
        raise ValueError(op)

    out_ndim = values.ndim
    return shard_map(
        local,
        mesh=mesh,
        in_specs=(P(d, *(None,) * (values.ndim - 1)), P(d), P(d)),
        out_specs=P(*(None,) * out_ndim),
        check_rep=False,
    )(values, segment_ids, mask)


def mp_edge_softmax(
    scores: jax.Array,
    segment_ids: jax.Array,
    num_segments: int,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Numerically-stable softmax over edges grouped by destination,
    composed from the mesh-aware primitives (which pad odd edge counts to
    mesh divisibility internally)."""
    mesh, daxes, n_data = _mp_mesh()
    if mesh is None or n_data == 1:
        return edge_softmax(scores, segment_ids, num_segments, mask=mask)
    seg_max = mp_segment_reduce(scores, segment_ids, num_segments, "max",
                                mask=mask)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(scores - mp_gather(seg_max, segment_ids))
    if mask is not None:
        mshape = mask.shape + (1,) * (scores.ndim - mask.ndim)
        ex = jnp.where(mask.reshape(mshape), ex, 0.0)
    denom = mp_segment_reduce(ex, segment_ids, num_segments, "sum")
    return ex / jnp.maximum(mp_gather(denom, segment_ids), 1e-16)


def in_degrees(graph) -> jax.Array:
    ones = graph.edge_mask.astype(jnp.int32)
    return jax.ops.segment_sum(
        ones, graph.dst, num_segments=graph.n_vertices, indices_are_sorted=True
    )


def out_degrees(graph) -> jax.Array:
    ones = graph.t_mask.astype(jnp.int32)
    return jax.ops.segment_sum(
        ones, graph.t_src, num_segments=graph.n_vertices, indices_are_sorted=True
    )
