"""Communication accounting: replicated vs partitioned bytes per superstep.

The replicated scheme (``repro.graph.ops`` mp_* path) keeps every vertex
field on every chip; a superstep's neighbor aggregation produces a full
``[N]`` partial per shard that one ring all-reduce combines — each device
moves ``2·(S-1)/S·N`` values regardless of how local the graph is.

The partitioned scheme moves only the halo: each ghost value travels once
from its owner to each reader. Two figures are reported —

* ``payload`` — the real entries exchanged (sum of per-(owner, reader)
  halo counts); what an ideal variable-length transport would move;
* ``padded`` — what our static-shape ``all_to_all`` actually moves
  (``S² · pair_cap`` values), the honest figure for this implementation.

Both are per *one f32-field pull superstep*; multiply by live field count
and dtype width for a program-level estimate. ``benchmarks/palgol_mesh.py``
serializes this report to ``BENCH_palgol_mesh.json``.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.plan import ByteCostModel
from repro.graph.partition.partitioner import (
    PartitionedGraph,
    partition_graph,
)


def partition_stats(pg: PartitionedGraph) -> Dict:
    """Structural invariant summary of one partition."""
    starts = np.asarray(pg.starts, dtype=np.int64)
    sizes = starts[1:] - starts[:-1]
    pull_counts = np.asarray(pg.emask).sum(axis=1)
    push_counts = np.asarray(pg.t_emask).sum(axis=1)
    ghosts_in = (np.asarray(pg.halo_in.ghost_ids) < pg.n_vertices).sum(axis=1)
    ghosts_out = (np.asarray(pg.halo_out.ghost_ids) < pg.n_vertices).sum(axis=1)
    return {
        "n_vertices": pg.n_vertices,
        "n_edges": pg.n_edges,
        "n_shards": pg.n_shards,
        "v_max": pg.v_max,
        "e_max": pg.e_max,
        "shard_sizes": sizes.tolist(),
        "pull_edges_per_shard": pull_counts.tolist(),
        "push_edges_per_shard": push_counts.tolist(),
        "halo_in_per_shard": ghosts_in.tolist(),
        "halo_out_per_shard": ghosts_out.tolist(),
        "halo_total": int(ghosts_in.sum()),
        "halo_pair_cap": pg.halo_in.pair_cap,
    }


def comm_bytes_report(
    graph,
    n_shards: int,
    bytes_per_value: int = 4,
    pg: Optional[PartitionedGraph] = None,
) -> Dict:
    """Bytes moved per pull superstep, replicated vs partitioned.

    Aggregate across all devices, for one f32 vertex field:

    * replicated: ring all-reduce of the ``[N]`` partials —
      ``S · 2·(S-1)/S · N·b = 2·(S-1)·N·b``;
    * partitioned payload: each real halo entry moved once, owner→reader;
    * partitioned padded: the static-shape ``all_to_all`` cost,
      ``S²·pair_cap·b``.
    """
    if pg is None:
        pg = partition_graph(graph, n_shards)
    stats = partition_stats(pg)
    n, b, S = pg.n_vertices, bytes_per_value, pg.n_shards
    replicated = 2 * (S - 1) * n * b
    payload = stats["halo_total"] * b
    padded = S * S * pg.halo_in.pair_cap * b
    return {
        "partition": stats,
        "bytes_per_value": b,
        "replicated_bytes_per_superstep": replicated,
        "partitioned_payload_bytes_per_superstep": payload,
        "partitioned_padded_bytes_per_superstep": padded,
        # None (JSON null) when the halo is empty — float('inf') would
        # serialize as the non-standard `Infinity` token
        "reduction_vs_replicated": (
            None if padded == 0 else replicated / padded
        ),
        "vertices_per_halo_entry": (
            None
            if stats["halo_total"] == 0
            else n / stats["halo_total"]
        ),
    }


def request_dedup_report(
    idx,
    n_vertices: int,
    bytes_per_value: int = 4,
    reply_width: int = 1,
) -> Dict:
    """Measured wire effect of ``gather_global``'s request dedup pass.

    ``idx`` is one round's request set (a chain-access indirection field,
    e.g. S-V's ``D``). ``raw`` is one slot per live requester — what the
    pre-dedup bucketing shipped; ``deduped`` is one slot per *distinct*
    target — what the unique-pass ships now. The gap is the modeled
    combining advantage (``combined_request_set``) turned into measured
    bytes: requests ship ids, replies ship ``reply_width`` values each.
    """
    idx = np.asarray(idx)
    live = idx[(idx >= 0) & (idx < n_vertices)]
    raw = int(live.size)
    ded = int(np.unique(live).size)
    per_slot = bytes_per_value * (1 + reply_width)  # request id + reply
    return {
        "raw_request_slots": raw,
        "deduped_request_slots": ded,
        "raw_bytes": raw * per_slot,
        "deduped_bytes": ded * per_slot,
        "dedup_factor": None if ded == 0 else raw / ded,
    }


def byte_cost_model(
    graph,
    n_shards: int,
    bytes_per_value: int = 4,
    pg: Optional[PartitionedGraph] = None,
    request_set: Optional[int] = None,
    combined_request_set: Optional[int] = None,
    superstep_overhead_bytes: int = 0,
) -> ByteCostModel:
    """Instrument a :class:`~repro.core.plan.ByteCostModel` from the
    partitioned layout — the plug between this layer's measured structure
    and the plan IR's byte-aware ``auto`` selector.

    * ``halo_bytes`` — the static halo payload one neighborhood round
      actually moves (``partition_stats``'s per-(owner, reader) counts);
    * ``update_bytes`` — one remote-write reduce-scatter, charged at the
      same halo payload (remote writes in the stdlib target neighbors or
      chain endpoints, both boundary-shaped);
    * ``request_set`` — live requesters per dynamic chain round. Defaults
      to ``n_vertices`` (every vertex reads its chain — the dense dryrun
      regime); pass a measured active-set size (e.g. the frontier of a
      converging pointer-jumping round, or ``halo_total`` for a
      boundary-only access pattern) to model the sparse regimes where
      naive/push beat pull;
    * ``combined_request_set`` — requesters after message combining
      (push); defaults to ``request_set``.
    """
    if pg is None:
        pg = partition_graph(graph, n_shards)
    stats = partition_stats(pg)
    halo_bytes = stats["halo_total"] * bytes_per_value
    return ByteCostModel(
        n_vertices=pg.n_vertices,
        value_bytes=bytes_per_value,
        request_set=request_set,
        combined_request_set=combined_request_set,
        halo_bytes=halo_bytes,
        update_bytes=halo_bytes,
        superstep_overhead_bytes=superstep_overhead_bytes,
    )
