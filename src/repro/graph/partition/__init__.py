"""Partitioned vertex state with halo exchange (`repro.graph.partition`).

Ends the replicated-state scaling wall: instead of every chip holding every
vertex field (the vertex-cut-over-edges scheme of ``repro.graph.ops``), the
vertex id space is split into contiguous, edge-balanced ranges — one per
shard — and each superstep moves only *boundary* state:

* :mod:`~repro.graph.partition.partitioner` — the edge-balanced greedy
  prefix-split partitioner and the :class:`PartitionedGraph` pytree
  (per-shard local COO with remapped local ids, static halo indices,
  owner maps);
* :mod:`~repro.graph.partition.halo` — shard_map collectives:
  ``halo_exchange`` (static ghost reads), ``gather_global`` (dynamic
  request/reply reads — pointer doubling rebuilds its request set from the
  current indirection field every round), ``scatter_reduce`` (combiner-aware
  reduce-scatter for remote writes);
* :mod:`~repro.graph.partition.executor` — ``run_bsp_partitioned``: the
  ``placement="partitioned"`` path of ``repro.pregel.run_bsp``, executing
  unchanged Palgol programs over the partitioned layout;
* :mod:`~repro.graph.partition.stats` — communication accounting feeding
  ``benchmarks/palgol_mesh.py``, and ``byte_cost_model`` — the measured
  halo/request-set figures instrumented into a
  :class:`repro.core.plan.ByteCostModel` for the byte-aware ``auto``
  schedule selector.
"""

from repro.graph.partition.partitioner import (  # noqa: F401
    HaloSpec,
    PartitionedGraph,
    edge_balanced_ranges,
    partition_field,
    partition_fields,
    partition_graph,
    unpartition_field,
    unpartition_fields,
)
from repro.graph.partition.executor import (  # noqa: F401
    run_bsp_partitioned,
)
from repro.graph.partition.stats import (  # noqa: F401
    byte_cost_model,
    comm_bytes_report,
    partition_stats,
    request_dedup_report,
)
