"""Edge-balanced contiguous-range vertex partitioning (host-side).

The partitioner splits the vertex id space ``[0, N)`` into ``S`` contiguous
ranges by a greedy prefix split on the degree CSR: walking vertices in id
order, a range boundary is cut whenever the cumulative edge-endpoint count
crosses the next multiple of ``total/S``. Contiguous ranges keep the
owner map a tiny ``[S+1]`` boundary array (owner lookup is a searchsorted,
not an ``[N]`` table) and make every per-shard edge block a *slice* of the
globally sorted COO — local ids stay sorted, so segment reductions keep
``indices_are_sorted=True``. Fancier strategies (METIS-style min-cut,
degree-aware relabeling) plug in by replacing :func:`edge_balanced_ranges`;
everything downstream consumes only the boundary array.

Edge assignment follows ownership of the *segment* vertex so reductions
never cross shards:

* pull ordering (sorted by ``dst``): an edge lives with ``dst``'s owner;
* push ordering (sorted by ``src``): with ``src``'s owner.

The neighbor endpoint of each local edge is remapped to *halo-local*
addressing: owned vertices keep their local row id ``g - start``, foreign
vertices get ``v_max + position`` in the shard's sorted ghost list. The
ghost lists and the per-(owner, reader) exchange indices are static — built
once per graph — so a superstep's halo exchange is two precomputed gathers
around one ``all_to_all`` (see :mod:`repro.graph.partition.halo`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class HaloSpec:
    """Static exchange plan for one edge ordering's ghost vertices.

    ``ghost_ids[s]`` are the global ids shard ``s`` reads but does not own,
    sorted ascending (padding: ``n_vertices``). ``send_local[i, j]`` are
    owner-``i``-local row ids of the values shard ``j`` needs (padding:
    ``v_max`` — clipped reads, never consumed); ``recv_pos[j, i]`` are the
    slots in ``j``'s ghost buffer where values from owner ``i`` land
    (padding: ``n_ghost`` — a dump slot sliced off after scatter).
    """

    ghost_ids: jax.Array  # i32[S, H]
    send_local: jax.Array  # i32[S, S, Hp]  indexed [owner, reader, slot]
    recv_pos: jax.Array  # i32[S, S, Hp]  indexed [reader, owner, slot]
    n_ghost: int = dataclasses.field(metadata=dict(static=True))  # H
    pair_cap: int = dataclasses.field(metadata=dict(static=True))  # Hp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Per-shard graph blocks + owner maps + halo plans (a pytree).

    All per-shard arrays carry a leading ``[S]`` dimension so the whole
    structure shards over a 1-D ``("shard",)`` mesh with ``P("shard")`` on
    that dimension (``starts`` is replicated). Vertex fields partition to
    ``[S, v_max]`` via :func:`partition_field`.
    """

    starts: jax.Array  # i32[S+1] contiguous range boundaries (owner map)
    vmask: jax.Array  # bool[S, v_max] valid local rows
    # pull ordering: edges assigned to dst's owner, sorted by local dst
    src_g: jax.Array  # i32[S, e_max] global src (value semantics)
    src_h: jax.Array  # i32[S, e_max] halo-local src (local row | v_max+pos)
    dst_l: jax.Array  # i32[S, e_max] local dst row (ascending; pad v_max)
    w: jax.Array  # f32[S, e_max]
    emask: jax.Array  # bool[S, e_max]
    # push ordering: edges assigned to src's owner, sorted by local src
    t_dst_g: jax.Array  # i32[S, e_max]
    t_dst_h: jax.Array  # i32[S, e_max]
    t_src_l: jax.Array  # i32[S, e_max]
    t_w: jax.Array  # f32[S, e_max]
    t_emask: jax.Array  # bool[S, e_max]
    halo_in: HaloSpec  # ghosts read by the pull ordering (srcs)
    halo_out: HaloSpec  # ghosts read by the push ordering (dsts)
    # static metadata
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))
    n_shards: int = dataclasses.field(metadata=dict(static=True))
    v_max: int = dataclasses.field(metadata=dict(static=True))
    e_max: int = dataclasses.field(metadata=dict(static=True))

    @property
    def sentinel(self) -> int:
        return self.n_vertices


def edge_balanced_ranges(graph, n_shards: int) -> np.ndarray:
    """Greedy prefix split on the degree CSR → boundaries ``i64[S+1]``.

    Balances the per-shard *assigned edge* count: each vertex weighs its
    in-degree (pull edges it owns) + out-degree (push edges) + 1 (so
    isolated vertices still spread). The greedy cut guarantees every
    shard's weight ≤ ``total/S + max_vertex_weight`` (the classic prefix
    bound), and each shard owns at least one vertex.
    """
    n = graph.n_vertices
    if n_shards < 1:
        raise ValueError("n_shards must be >= 1")
    if n < n_shards:
        raise ValueError(
            f"cannot give each of {n_shards} shards a vertex: only {n} exist"
        )
    dst = np.asarray(graph.dst)[np.asarray(graph.edge_mask)]
    t_src = np.asarray(graph.t_src)[np.asarray(graph.t_mask)]
    weight = np.ones(n, dtype=np.int64)
    np.add.at(weight, dst, 1)
    np.add.at(weight, t_src, 1)
    cum = np.cumsum(weight)
    total = int(cum[-1])
    bounds = np.zeros(n_shards + 1, dtype=np.int64)
    bounds[n_shards] = n
    for k in range(1, n_shards):
        target = total * k / n_shards
        cut = int(np.searchsorted(cum, target, side="left")) + 1
        # keep ≥1 vertex per shard on both sides of the cut
        cut = max(cut, int(bounds[k - 1]) + 1)
        cut = min(cut, n - (n_shards - k))
        bounds[k] = cut
    return bounds


def _build_halo(
    nbr_global: np.ndarray,  # [S, e_max] global neighbor ids (pad: N)
    emask: np.ndarray,  # [S, e_max]
    bounds: np.ndarray,  # [S+1]
    n: int,
    v_max: int,
):
    """Ghost lists + exchange plan + halo-local remap for one ordering.

    Returns ``(halo_spec_arrays, nbr_halo)`` where ``nbr_halo[s, e]`` is the
    halo-local address of ``nbr_global[s, e]`` on shard ``s``.
    """
    S = len(bounds) - 1
    ghosts = []
    for s in range(S):
        ids = np.unique(nbr_global[s][emask[s]])
        own = (ids >= bounds[s]) & (ids < bounds[s + 1])
        ghosts.append(ids[~own].astype(np.int64))
    H = max((len(g) for g in ghosts), default=0)
    ghost_ids = np.full((S, H), n, dtype=np.int32)
    for s, g in enumerate(ghosts):
        ghost_ids[s, : len(g)] = g

    # per-(owner, reader) slices of each reader's sorted ghost list
    pair_count = np.zeros((S, S), dtype=np.int64)
    pair_lo = np.zeros((S, S), dtype=np.int64)
    for j in range(S):
        lo = np.searchsorted(ghosts[j], bounds[:-1], side="left")
        hi = np.searchsorted(ghosts[j], bounds[1:], side="left")
        pair_lo[:, j] = lo
        pair_count[:, j] = hi - lo
    Hp = int(pair_count.max(initial=0))
    send_local = np.full((S, S, Hp), v_max, dtype=np.int32)
    recv_pos = np.full((S, S, Hp), H, dtype=np.int32)
    for i in range(S):
        for j in range(S):
            c = int(pair_count[i, j])
            if c == 0:
                continue
            lo = int(pair_lo[i, j])
            ids = ghosts[j][lo : lo + c]
            send_local[i, j, :c] = ids - bounds[i]
            recv_pos[j, i, :c] = np.arange(lo, lo + c)

    # halo-local remap of the neighbor endpoints
    nbr_halo = np.full(nbr_global.shape, v_max + H, dtype=np.int32)
    for s in range(S):
        m = emask[s]
        g = nbr_global[s][m]
        own = (g >= bounds[s]) & (g < bounds[s + 1])
        loc = np.where(
            own,
            g - bounds[s],
            v_max + np.searchsorted(ghosts[s], g),
        )
        nbr_halo[s, m] = loc.astype(np.int32)
    return (ghost_ids, send_local, recv_pos, H, Hp), nbr_halo


def _shard_edges(key, other, w, mask, bounds, v_max):
    """Slice one globally key-sorted COO into per-shard blocks.

    Returns (key_local [S,e_max], other_global [S,e_max], w, mask) with the
    padding conventions of :class:`PartitionedGraph`.
    """
    S = len(bounds) - 1
    key = np.asarray(key)[np.asarray(mask)]
    other = np.asarray(other)[np.asarray(mask)]
    w = np.asarray(w)[np.asarray(mask)]
    lo = np.searchsorted(key, bounds[:-1], side="left")
    hi = np.searchsorted(key, bounds[1:], side="left")
    counts = hi - lo
    e_max = int(counts.max(initial=0))
    n = int(bounds[-1])
    key_l = np.full((S, e_max), v_max, dtype=np.int32)
    oth_g = np.full((S, e_max), n, dtype=np.int32)
    w_p = np.zeros((S, e_max), dtype=np.float32)
    m_p = np.zeros((S, e_max), dtype=bool)
    for s in range(S):
        c = int(counts[s])
        key_l[s, :c] = key[lo[s] : hi[s]] - bounds[s]
        oth_g[s, :c] = other[lo[s] : hi[s]]
        w_p[s, :c] = w[lo[s] : hi[s]]
        m_p[s, :c] = True
    return key_l, oth_g, w_p, m_p, e_max


def partition_graph(
    graph, n_shards: int, bounds: Optional[np.ndarray] = None
) -> PartitionedGraph:
    """Partition a dense :class:`~repro.graph.structure.Graph` into ``S``
    edge-balanced contiguous-range shards with static halo plans."""
    n = graph.n_vertices
    if bounds is None:
        bounds = edge_balanced_ranges(graph, n_shards)
    bounds = np.asarray(bounds, dtype=np.int64)
    if len(bounds) != n_shards + 1 or bounds[0] != 0 or bounds[-1] != n:
        raise ValueError("bounds must be [0, ..., n_vertices] of length S+1")
    v_max = int(np.max(bounds[1:] - bounds[:-1]))

    dst_l, src_g, w_p, m_p, e_pull = _shard_edges(
        graph.dst, graph.src, graph.weight, graph.edge_mask, bounds, v_max
    )
    tsrc_l, tdst_g, tw_p, tm_p, e_push = _shard_edges(
        graph.t_src, graph.t_dst, graph.t_weight, graph.t_mask, bounds, v_max
    )
    e_max = max(e_pull, e_push, 1)

    def repad(key_l, oth_g, w, m):
        S, e = key_l.shape
        if e == e_max:
            return key_l, oth_g, w, m
        pad = e_max - e
        return (
            np.pad(key_l, ((0, 0), (0, pad)), constant_values=v_max),
            np.pad(oth_g, ((0, 0), (0, pad)), constant_values=n),
            np.pad(w, ((0, 0), (0, pad))),
            np.pad(m, ((0, 0), (0, pad))),
        )

    dst_l, src_g, w_p, m_p = repad(dst_l, src_g, w_p, m_p)
    tsrc_l, tdst_g, tw_p, tm_p = repad(tsrc_l, tdst_g, tw_p, tm_p)

    (gi, sl, rp, H_in, Hp_in), src_h = _build_halo(src_g, m_p, bounds, n, v_max)
    halo_in = HaloSpec(
        ghost_ids=jnp.asarray(gi), send_local=jnp.asarray(sl),
        recv_pos=jnp.asarray(rp), n_ghost=H_in, pair_cap=Hp_in,
    )
    (gi_o, sl_o, rp_o, H_out, Hp_out), tdst_h = _build_halo(
        tdst_g, tm_p, bounds, n, v_max
    )
    halo_out = HaloSpec(
        ghost_ids=jnp.asarray(gi_o), send_local=jnp.asarray(sl_o),
        recv_pos=jnp.asarray(rp_o), n_ghost=H_out, pair_cap=Hp_out,
    )

    sizes = (bounds[1:] - bounds[:-1])[:, None]
    vmask = np.arange(v_max)[None, :] < sizes
    return PartitionedGraph(
        starts=jnp.asarray(bounds, jnp.int32),
        vmask=jnp.asarray(vmask),
        src_g=jnp.asarray(src_g),
        src_h=jnp.asarray(src_h),
        dst_l=jnp.asarray(dst_l),
        w=jnp.asarray(w_p),
        emask=jnp.asarray(m_p),
        t_dst_g=jnp.asarray(tdst_g),
        t_dst_h=jnp.asarray(tdst_h),
        t_src_l=jnp.asarray(tsrc_l),
        t_w=jnp.asarray(tw_p),
        t_emask=jnp.asarray(tm_p),
        halo_in=halo_in,
        halo_out=halo_out,
        n_vertices=n,
        n_edges=int(np.asarray(graph.edge_mask).sum()),
        n_shards=n_shards,
        v_max=v_max,
        e_max=e_max,
    )


# ---------------------------------------------------------------------------
# field (de)partitioning — host-side layout shuffles


def _bounds_np(pg: PartitionedGraph) -> np.ndarray:
    return np.asarray(pg.starts, dtype=np.int64)


def partition_field(pg: PartitionedGraph, x) -> jax.Array:
    """``[N, ...]`` dense vertex field → ``[S, v_max, ...]`` shard blocks
    (padding rows zero-filled; they are masked inactive by the executor)."""
    x = jnp.asarray(x)
    bounds = _bounds_np(pg)
    idx = bounds[:-1, None] + np.arange(pg.v_max)[None, :]
    valid = idx < bounds[1:, None]
    gathered = jnp.take(x, jnp.asarray(np.clip(idx, 0, pg.n_vertices - 1)), axis=0)
    vshape = valid.shape + (1,) * (gathered.ndim - 2)
    return jnp.where(
        jnp.asarray(valid).reshape(vshape), gathered, jnp.zeros((), x.dtype)
    )


def unpartition_field(pg: PartitionedGraph, y) -> jax.Array:
    """``[S, v_max, ...]`` shard blocks → ``[N, ...]`` dense vertex field."""
    y = jnp.asarray(y)
    bounds = _bounds_np(pg)
    g = np.arange(pg.n_vertices, dtype=np.int64)
    owner = np.searchsorted(bounds, g, side="right") - 1
    flat_pos = owner * pg.v_max + (g - bounds[owner])
    flat = y.reshape((pg.n_shards * pg.v_max,) + y.shape[2:])
    return jnp.take(flat, jnp.asarray(flat_pos), axis=0)


def partition_fields(pg: PartitionedGraph, fields: Dict) -> Dict:
    return {k: partition_field(pg, v) for k, v in fields.items()}


def unpartition_fields(pg: PartitionedGraph, fields: Dict) -> Dict:
    return {k: unpartition_field(pg, v) for k, v in fields.items()}
