"""Halo-exchange collectives for partitioned vertex state (shard_map body).

Every function here runs *inside* a ``shard_map`` over a 1-D ``("shard",)``
mesh; arguments are per-shard blocks (no leading ``[S]`` dimension). Three
communication primitives cover all of Palgol's remote data access:

``halo_exchange``
    Static ghost reads: the owner gathers the boundary values its neighbors
    need (``send_local``), one ``all_to_all`` moves them, the reader
    scatters them into its ghost buffer (``recv_pos``). Per superstep this
    moves only the halo — O(boundary), not O(N) — which is the whole point
    of the subsystem. Used for neighborhood communication (``F[e.id]``),
    whose access set is the static edge structure.

``gather_global``
    Dynamic one-sided reads at arbitrary global vertex ids (chain access:
    ``D[D[u]]``): requests are bucketed by owner, one ``all_to_all`` ships
    the request ids, owners gather locally, a second ``all_to_all`` ships
    the replies. Pull-mode pointer doubling calls this once per doubling
    round — the request set ("the halo") is rebuilt from the *current*
    indirection field each round, exactly the paper's remote-read staging
    but with partitioned instead of replicated state.

``scatter_reduce``
    Remote writes (``remote F[t] op= v``): each shard pre-combines its
    messages into an identity-filled ``[S·v_max]`` buffer, then a
    reduce-scatter (``psum_scatter`` for ``sum``; ``all_to_all`` + a local
    tree-combine for the other monoids) lands each owner's combined delta.
    Targets are data-dependent, so unlike ``halo_exchange`` this pays
    O(N/S·S) worst-case — the price of Palgol's arbitrary remote writes.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.graph import ops as gops

AXIS = "shard"


def halo_exchange(
    x: jax.Array,  # [v_max, ...] per-shard field block
    send_local: jax.Array,  # i32[S, Hp] owner-local rows to send, per reader
    recv_pos: jax.Array,  # i32[S, Hp] ghost-buffer slots, per owner
    n_ghost: int,
    axis: str = AXIS,
) -> jax.Array:
    """Static halo gather → ghost values ``[n_ghost, ...]`` for this shard."""
    if n_ghost == 0:
        return jnp.zeros((0,) + x.shape[1:], x.dtype)
    vals = gops.gather(x, send_local)  # [S, Hp, ...] (pad rows clip: unread)
    recv = jax.lax.all_to_all(vals, axis, split_axis=0, concat_axis=0)
    ghost = jnp.zeros((n_ghost + 1,) + x.shape[1:], x.dtype)
    ghost = ghost.at[recv_pos].set(recv, mode="drop")
    return ghost[:n_ghost]


def _owner_of(idx: jax.Array, starts: jax.Array, n_shards: int) -> jax.Array:
    """Owner shard of each (already clipped) global vertex id."""
    return jnp.clip(
        jnp.searchsorted(starts, idx, side="right") - 1, 0, n_shards - 1
    ).astype(jnp.int32)


def _owner_and_slot(idx: jax.Array, starts: jax.Array, n_shards: int):
    """Owner shard and within-bucket slot for each (clipped) global id."""
    owner = _owner_of(idx, starts, n_shards)
    onehot = owner[:, None] == jnp.arange(n_shards, dtype=jnp.int32)[None, :]
    slot = (
        jnp.take_along_axis(
            jnp.cumsum(onehot.astype(jnp.int32), axis=0), owner[:, None], axis=1
        )[:, 0]
        - 1
    )
    return owner, slot


def gather_global(
    x: jax.Array,  # [v_max, ...] per-shard field block
    idx: jax.Array,  # i32[K] global vertex ids (may include the sentinel N)
    starts: jax.Array,  # i32[S+1] owner map (replicated)
    n_vertices: int,
    v_max: int,
    fill=None,
    axis: str = AXIS,
    dedup: bool = True,
) -> jax.Array:
    """Dynamic read of ``field[idx]`` across shards (request/reply).

    Matches :func:`repro.graph.ops.gather` semantics: with ``fill=None``
    out-of-range ids clip (read vertex ``N-1``); otherwise they read
    ``fill``. Two ``all_to_all`` rounds, ``2·S·K`` values of traffic per
    shard — the honest wire cost of data-dependent remote reads.

    ``dedup=True`` (default) combines duplicate requests before bucketing
    — one request slot and one reply per *distinct* target id (Pregel
    message combining on the request side; replies fan back out through
    the inverse permutation at the requester). The exchange shapes stay
    static, but every duplicate collapses to the padding sentinel, so the
    live payload shrinks to the combined request set — what the push byte
    model (:class:`repro.core.plan.ByteCostModel.combined_request_set`)
    charges for.
    """
    (k,) = idx.shape
    n_shards = starts.shape[0] - 1
    if n_shards == 1:
        return gops.gather(x, jnp.where(idx >= n_vertices, v_max, idx), fill)
    if dedup and k > 1:
        uniq, inv = jnp.unique(
            idx, return_inverse=True, size=k, fill_value=n_vertices
        )
        vals = gather_global(
            x, uniq.astype(idx.dtype), starts, n_vertices, v_max,
            fill=fill, axis=axis, dedup=False,
        )
        return vals[inv.reshape(-1)]
    idxc = jnp.clip(idx, 0, n_vertices - 1)
    owner, slot = _owner_and_slot(idxc, starts, n_shards)
    local = (idxc - starts[owner]).astype(jnp.int32)
    req = jnp.full((n_shards, k), v_max, jnp.int32)
    req = req.at[owner, slot].set(local)
    req_t = jax.lax.all_to_all(req, axis, split_axis=0, concat_axis=0)
    vals = gops.gather(x, req_t)  # [S, K, ...]; padded slots clip, unread
    vals_t = jax.lax.all_to_all(vals, axis, split_axis=0, concat_axis=0)
    out = vals_t[owner, slot]
    if fill is not None:
        import numpy as np

        fv = jnp.asarray(np.asarray(fill, np.dtype(x.dtype)).item(), x.dtype)
        oob = jnp.logical_or(idx < 0, idx >= n_vertices)
        oshape = oob.shape + (1,) * (out.ndim - oob.ndim)
        out = jnp.where(oob.reshape(oshape), fv, out)
    return out


def scatter_reduce(
    idx: jax.Array,  # i32[K] global target ids
    values: jax.Array,  # [K, ...] message payloads
    op: str,
    starts: jax.Array,  # i32[S+1]
    n_vertices: int,
    v_max: int,
    mask: Optional[jax.Array] = None,
    axis: str = AXIS,
) -> jax.Array:
    """Combine remote-write messages onto their owners → ``[v_max, ...]``.

    Returns each shard's *delta*: the combiner-fold of every message
    targeting its owned rows, identity where no message arrived. The caller
    folds the delta into the live field (receiver-side masking stays local
    to the owner). Out-of-range / masked targets are dropped, matching
    ``scatter_combine``'s ``mode="drop"``.
    """
    n_shards = starts.shape[0] - 1
    bool_io = values.dtype == jnp.bool_
    if bool_io:  # or/and combine via int min/max, as repro.graph.ops does
        values = values.astype(jnp.int32)
        op_eff = {"or": "max", "and": "min"}.get(op, op)
    else:
        op_eff = op
    ident = gops._identity_for(op_eff, values.dtype)
    padded = jnp.full((n_shards * v_max,) + values.shape[1:], ident)
    idxc = jnp.clip(idx, 0, n_vertices - 1)
    owner = _owner_of(idxc, starts, n_shards)
    pos = owner * v_max + (idxc - starts[owner])
    oob = jnp.logical_or(idx < 0, idx >= n_vertices)
    if mask is not None:
        oob = jnp.logical_or(oob, ~mask)
    pos = jnp.where(oob, n_shards * v_max, pos)  # out-of-range ⇒ dropped
    padded = gops.scatter_combine(padded, pos, values, op_eff)
    if n_shards == 1:
        out = padded
    elif op_eff == "sum":
        out = jax.lax.psum_scatter(padded, axis, scatter_dimension=0, tiled=True)
    else:
        blocks = padded.reshape((n_shards, v_max) + padded.shape[1:])
        recv = jax.lax.all_to_all(blocks, axis, split_axis=0, concat_axis=0)
        out = gops.combine_along_axis(op_eff, recv, axis=0)
    if bool_io:
        thresh = {"or": jnp.maximum(out, 0) > 0, "and": jnp.minimum(out, 1) > 0}
        return thresh[op] if op in thresh else out.astype(jnp.bool_)
    return out
