"""`placement="partitioned"` execution of Palgol programs.

``run_bsp_partitioned`` is the partitioned twin of
:func:`repro.pregel.runtime.run_bsp`: the same host-side program-plan walk
(:func:`repro.pregel.runtime.walk_plan` — Seq/Iter/Stop sequencing,
fixed-point aggregator round-trips, fused superstep counting, frontier
instrumentation), but each **fused superstep** executes as ONE shard_map
dispatch over the :class:`~repro.graph.partition.partitioner.PartitionedGraph`
layout. Inside the shard_map body the unchanged
:class:`~repro.core.codegen.StepExecutor` runs one plan op at a time
(:func:`~repro.core.codegen.exec_plan_part`) with a :class:`ShardComm`,
mapping ops onto the halo collectives:

* ``ReadRound`` for neighborhood sends (``F[e.id]``) → static
  :func:`~.halo.halo_exchange` (moves only boundary state);
* ``ReadRound`` for chain accesses (``D[D[u]]``) →
  :func:`~.halo.gather_global` — once per pull round (pointer doubling
  rebuilds its request halo from the current indirection field), once
  per hop under ``schedule="naive"``, once per ``push_reply`` round under
  ``schedule="push"`` (the deduplicated request bucketing inside
  gather_global *is* the combined request set);
* ``RemoteUpdate`` → :func:`~.halo.scatter_reduce` + a local fold at the
  owner.

A *merged* superstep of the fused plan (§4.3) runs its parts inside the
same dispatch: the halo exchange of a step's first ReadRound piggybacks on
the merged RemoteUpdate's reduce-scatter — one barrier, both collectives —
and the per-shard mailbox (chain/neighborhood buffers, pending remote
payloads) crosses dispatch boundaries as sharded ``[S, ...]`` arrays.

Superstep accounting is the walk itself — one count per dispatched (fused)
superstep, the identical plan the staged dense executor dispatches — so
STM cross-checks carry over by construction, for every schedule and both
``fuse`` settings.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.core import plan as plan_mod
from repro.core.codegen import HALTED, _EdgeCtx, exec_plan_part
from repro.core.plan import ByteCostModel
from repro.graph import ops as gops
from repro.graph.partition import halo
from repro.graph.partition.partitioner import (
    PartitionedGraph,
    partition_fields,
    partition_graph,
    unpartition_fields,
)
from repro.pregel.runtime import BSPResult, walk_plan

AXIS = halo.AXIS


class ShardComm:
    """Per-shard communication context (lives inside a shard_map body).

    Implements the addressing contract of
    :class:`~repro.core.codegen.StepExecutor`: ``n_rows`` local rows per
    shard (``v_max``), global vertex ids as values, halo-layer collectives
    for every access that leaves the shard.
    """

    def __init__(self, pg: PartitionedGraph, axis: str = AXIS):
        self.pg = pg
        self.axis = axis
        self.n_rows = pg.v_max
        self.valid = pg.vmask
        self.start = pg.starts[jax.lax.axis_index(axis)]

    def ids(self) -> jax.Array:
        """Global ids of this shard's rows (padding rows run past the
        range; they are masked inactive everywhere)."""
        return (self.start + jnp.arange(self.n_rows, dtype=jnp.int32)).astype(
            jnp.int32
        )

    def gather(self, arr: jax.Array, idx: jax.Array, fill=None) -> jax.Array:
        """``arr[idx]`` for arbitrary *global* ids (dynamic exchange)."""
        idx = jnp.asarray(idx, jnp.int32)
        flat = halo.gather_global(
            arr,
            idx.reshape(-1),
            self.pg.starts,
            self.pg.n_vertices,
            self.pg.v_max,
            fill=fill,
            axis=self.axis,
        )
        return flat.reshape(idx.shape + arr.shape[1:])

    def _halo_for(self, direction: str):
        return self.pg.halo_in if direction in ("in", "nbr") else self.pg.halo_out

    def read_edge(self, per_row: jax.Array, ectx: _EdgeCtx) -> jax.Array:
        """Per-edge neighbor values via the static halo (boundary-only)."""
        spec = self._halo_for(ectx.direction)
        ghost = halo.halo_exchange(
            per_row, spec.send_local, spec.recv_pos, spec.n_ghost, self.axis
        )
        ext = jnp.concatenate([per_row, ghost], axis=0)
        return gops.gather(ext, ectx.nbr_read)

    def edge_ctx(self, direction: str) -> _EdgeCtx:
        pg = self.pg
        if direction in ("in", "nbr"):
            seg, nbr_g, nbr_h, w, m = pg.dst_l, pg.src_g, pg.src_h, pg.w, pg.emask
        elif direction == "out":
            seg, nbr_g, nbr_h, w, m = (
                pg.t_src_l, pg.t_dst_g, pg.t_dst_h, pg.t_w, pg.t_emask,
            )
        else:
            raise ValueError(f"unknown edge direction {direction!r}")
        vid = (self.start + seg).astype(jnp.int32)
        return _EdgeCtx(
            direction, nbr=nbr_g, vid=vid, w=w, emask=m, seg=seg, nbr_read=nbr_h
        )

    def scatter_reduce(self, idx, values, op: str, mask) -> jax.Array:
        """Pre-combined remote-write delta for this shard's owned rows."""
        return halo.scatter_reduce(
            jnp.asarray(idx, jnp.int32),
            values,
            op,
            self.pg.starts,
            self.pg.n_vertices,
            self.pg.v_max,
            mask=mask,
            axis=self.axis,
        )


# ---------------------------------------------------------------------------
# shard_map plumbing


_SHARDED_PG_FIELDS = (
    "vmask", "src_g", "src_h", "dst_l", "w", "emask",
    "t_dst_g", "t_dst_h", "t_src_l", "t_w", "t_emask",
)
_SHARDED_HALO_FIELDS = ("ghost_ids", "send_local", "recv_pos")


def pg_partition_specs(pg: PartitionedGraph) -> PartitionedGraph:
    """PartitionSpec tree matching ``pg``: every per-shard leading dim over
    the ``shard`` axis, the owner map (``starts``) replicated."""
    sh = {f: P(AXIS) for f in _SHARDED_PG_FIELDS}
    hs = {f: P(AXIS) for f in _SHARDED_HALO_FIELDS}
    return dataclasses.replace(
        pg,
        starts=P(),
        halo_in=dataclasses.replace(pg.halo_in, **hs),
        halo_out=dataclasses.replace(pg.halo_out, **hs),
        **sh,
    )


def _local_view(pg: PartitionedGraph) -> PartitionedGraph:
    """Squeeze the per-shard leading dim off a shard_map block of ``pg``."""
    sq = {f: getattr(pg, f)[0] for f in _SHARDED_PG_FIELDS}
    return dataclasses.replace(
        pg,
        halo_in=dataclasses.replace(
            pg.halo_in, **{f: getattr(pg.halo_in, f)[0] for f in _SHARDED_HALO_FIELDS}
        ),
        halo_out=dataclasses.replace(
            pg.halo_out, **{f: getattr(pg.halo_out, f)[0] for f in _SHARDED_HALO_FIELDS}
        ),
        **sq,
    )


def _make_superstep_fn(ss: plan_mod.Superstep, pg: PartitionedGraph, mesh):
    """jit(shard_map(...)) executing ONE fused superstep's parts in order.

    ``(fields, mailbox, pg) -> (fields, mailbox)`` over per-shard blocks;
    the specs are pytree prefixes (every fields/mailbox leaf is a
    ``[S, ...]`` block over the ``shard`` axis), so mailbox keysets may
    differ between supersteps without bespoke spec plumbing. A merged
    superstep's collectives (e.g. a RemoteUpdate's reduce-scatter plus the
    next step's halo exchange) land in this one dispatch.
    """
    from jax.experimental.shard_map import shard_map

    tmap = jax.tree_util.tree_map

    def body(flds, mbox, pgb):
        pgl = _local_view(pgb)
        comm = ShardComm(pgl)
        local_f = {k: v[0] for k, v in flds.items()}
        local_m = tmap(lambda v: v[0], mbox)
        for ref in ss.parts:
            local_f, local_m = exec_plan_part(ref, pgl, comm, local_f, local_m)
        return (
            {k: v[None] for k, v in local_f.items()},
            tmap(lambda v: v[None], local_m),
        )

    return jax.jit(
        shard_map(
            body, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), pg_partition_specs(pg)),
            out_specs=(P(AXIS), P(AXIS)),
            check_rep=False,
        )
    )


# ---------------------------------------------------------------------------
# the runtime


def run_bsp_partitioned(
    prog,
    graph,
    fields: Dict[str, jax.Array],
    schedule: str = "pull",
    max_iters: int = 100_000,
    mesh=None,
    n_shards: int = None,
    byte_costs: Optional[ByteCostModel] = None,
    fuse: bool = True,
) -> BSPResult:
    """Execute a Palgol program over partitioned vertex state.

    Same contract as :func:`repro.pregel.runtime.run_bsp` (canonical field
    dict in, final *dense* fields + superstep count + trips + frontier
    sizes out); the graph is partitioned over ``mesh`` (default: a 1-D
    mesh over all local devices, built by
    :func:`repro.dist.sharding.shard_mesh`). Every schedule runs here
    (``pull``/``push``/``naive``/``auto`` — build byte costs from this
    layout with :func:`repro.graph.partition.byte_cost_model`), and
    ``fuse=True`` (default) dispatches the §4.3-fused program plan — one
    shard_map call per *fused* superstep, merged collectives combined in
    one dispatch; ``fuse=False`` dispatches the unfused per-op expansion.
    """
    from repro.dist import sharding as shd

    pp = plan_mod.lower_program(prog, schedule=schedule, byte_costs=byte_costs)
    if fuse:
        pp = plan_mod.fuse(pp)

    if mesh is None:
        mesh = shd.shard_mesh(n_shards)
    n_shards = mesh.shape[AXIS]
    pg = partition_graph(graph, n_shards)
    fields = {k: jnp.asarray(v) for k, v in fields.items()}
    if HALTED not in fields:
        fields[HALTED] = jnp.zeros((graph.n_vertices,), jnp.bool_)
    pfields = partition_fields(pg, fields)
    pfields = jax.device_put(
        pfields, shd.vertex_partition_shardings(pfields, mesh)
    )
    pg = jax.device_put(pg, shd.vertex_partition_shardings(pg, mesh))

    counter = [0]
    trips: List[int] = []
    active_sets: List[List[int]] = []
    ss_fns: Dict[int, object] = {}
    mailbox_box = [{}]

    def exec_superstep(ss: plan_mod.Superstep, flds):
        if id(ss) not in ss_fns:
            ss_fns[id(ss)] = _make_superstep_fn(ss, pg, mesh)
        flds, mailbox_box[0] = ss_fns[id(ss)](flds, mailbox_box[0], pg)
        return flds

    out = walk_plan(
        pp, pfields, exec_superstep, counter, trips, max_iters,
        active_sets=active_sets, vertex_ndim=2,
    )
    return BSPResult(
        fields=unpartition_fields(pg, out),
        supersteps=counter[0],
        trips=trips,
        active_sets=active_sets,
    )
