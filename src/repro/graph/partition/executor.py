"""`placement="partitioned"` execution of Palgol programs.

``run_bsp_partitioned`` is the partitioned twin of
:func:`repro.pregel.runtime.run_bsp`: the same host-side superstep walk
(Seq/Iter/Stop, fixed-point aggregator round-trips, superstep counting),
but each Palgol step executes as ONE shard_map dispatch over the
:class:`~repro.graph.partition.partitioner.PartitionedGraph` layout. Inside
the shard_map body the unchanged :class:`~repro.core.codegen.StepExecutor`
runs with a :class:`ShardComm`, folding the step's
:class:`~repro.core.plan.StepPlan` ops onto the halo collectives:

* ``ReadRound`` for neighborhood sends (``F[e.id]``) → static
  :func:`~.halo.halo_exchange` (moves only boundary state);
* ``ReadRound`` for chain accesses (``D[D[u]]``) →
  :func:`~.halo.gather_global` — once per pull round (pointer doubling
  rebuilds its request halo from the current indirection field), once
  per hop under ``schedule="naive"`` (the gather_global exchange *is* the
  request/reply pair, so the hop's two supersteps are charged honestly),
  and once per ``push_reply`` round under ``schedule="push"`` (the
  request bucketing inside gather_global *is* the combined request set —
  one slot per owner shard — so the paired ``push_request`` superstep's
  exchange is paid here; combined replies map onto the reply
  ``all_to_all``);
* ``RemoteUpdate`` → :func:`~.halo.scatter_reduce` + a local fold at the
  owner (the same combiner-aware reduce-scatter push-mode remote writes
  ride).

Superstep accounting is ``plan.n_supersteps`` — the identical plan the
staged dense executor dispatches — so STM cross-checks carry over by
construction, for every schedule (``pull``/``push``/``naive``/``auto``).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from repro.core import ast
from repro.core.codegen import HALTED, StepExecutor, _EdgeCtx, make_stop_fn
from repro.core.plan import ByteCostModel, StepPlan, lower_step
from repro.graph import ops as gops
from repro.graph.partition import halo
from repro.graph.partition.partitioner import (
    PartitionedGraph,
    partition_fields,
    partition_graph,
    unpartition_fields,
)
from repro.pregel.runtime import BSPResult, walk_program

AXIS = halo.AXIS


class ShardComm:
    """Per-shard communication context (lives inside a shard_map body).

    Implements the addressing contract of
    :class:`~repro.core.codegen.StepExecutor`: ``n_rows`` local rows per
    shard (``v_max``), global vertex ids as values, halo-layer collectives
    for every access that leaves the shard.
    """

    def __init__(self, pg: PartitionedGraph, axis: str = AXIS):
        self.pg = pg
        self.axis = axis
        self.n_rows = pg.v_max
        self.valid = pg.vmask
        self.start = pg.starts[jax.lax.axis_index(axis)]

    def ids(self) -> jax.Array:
        """Global ids of this shard's rows (padding rows run past the
        range; they are masked inactive everywhere)."""
        return (self.start + jnp.arange(self.n_rows, dtype=jnp.int32)).astype(
            jnp.int32
        )

    def gather(self, arr: jax.Array, idx: jax.Array, fill=None) -> jax.Array:
        """``arr[idx]`` for arbitrary *global* ids (dynamic exchange)."""
        idx = jnp.asarray(idx, jnp.int32)
        flat = halo.gather_global(
            arr,
            idx.reshape(-1),
            self.pg.starts,
            self.pg.n_vertices,
            self.pg.v_max,
            fill=fill,
            axis=self.axis,
        )
        return flat.reshape(idx.shape + arr.shape[1:])

    def _halo_for(self, direction: str):
        return self.pg.halo_in if direction in ("in", "nbr") else self.pg.halo_out

    def read_edge(self, per_row: jax.Array, ectx: _EdgeCtx) -> jax.Array:
        """Per-edge neighbor values via the static halo (boundary-only)."""
        spec = self._halo_for(ectx.direction)
        ghost = halo.halo_exchange(
            per_row, spec.send_local, spec.recv_pos, spec.n_ghost, self.axis
        )
        ext = jnp.concatenate([per_row, ghost], axis=0)
        return gops.gather(ext, ectx.nbr_read)

    def edge_ctx(self, direction: str) -> _EdgeCtx:
        pg = self.pg
        if direction in ("in", "nbr"):
            seg, nbr_g, nbr_h, w, m = pg.dst_l, pg.src_g, pg.src_h, pg.w, pg.emask
        elif direction == "out":
            seg, nbr_g, nbr_h, w, m = (
                pg.t_src_l, pg.t_dst_g, pg.t_dst_h, pg.t_w, pg.t_emask,
            )
        else:
            raise ValueError(f"unknown edge direction {direction!r}")
        vid = (self.start + seg).astype(jnp.int32)
        return _EdgeCtx(
            direction, nbr=nbr_g, vid=vid, w=w, emask=m, seg=seg, nbr_read=nbr_h
        )

    def scatter_reduce(self, idx, values, op: str, mask) -> jax.Array:
        """Pre-combined remote-write delta for this shard's owned rows."""
        return halo.scatter_reduce(
            jnp.asarray(idx, jnp.int32),
            values,
            op,
            self.pg.starts,
            self.pg.n_vertices,
            self.pg.v_max,
            mask=mask,
            axis=self.axis,
        )


# ---------------------------------------------------------------------------
# shard_map plumbing


_SHARDED_PG_FIELDS = (
    "vmask", "src_g", "src_h", "dst_l", "w", "emask",
    "t_dst_g", "t_dst_h", "t_src_l", "t_w", "t_emask",
)
_SHARDED_HALO_FIELDS = ("ghost_ids", "send_local", "recv_pos")


def pg_partition_specs(pg: PartitionedGraph) -> PartitionedGraph:
    """PartitionSpec tree matching ``pg``: every per-shard leading dim over
    the ``shard`` axis, the owner map (``starts``) replicated."""
    sh = {f: P(AXIS) for f in _SHARDED_PG_FIELDS}
    hs = {f: P(AXIS) for f in _SHARDED_HALO_FIELDS}
    return dataclasses.replace(
        pg,
        starts=P(),
        halo_in=dataclasses.replace(pg.halo_in, **hs),
        halo_out=dataclasses.replace(pg.halo_out, **hs),
        **sh,
    )


def _local_view(pg: PartitionedGraph) -> PartitionedGraph:
    """Squeeze the per-shard leading dim off a shard_map block of ``pg``."""
    sq = {f: getattr(pg, f)[0] for f in _SHARDED_PG_FIELDS}
    return dataclasses.replace(
        pg,
        halo_in=dataclasses.replace(
            pg.halo_in, **{f: getattr(pg.halo_in, f)[0] for f in _SHARDED_HALO_FIELDS}
        ),
        halo_out=dataclasses.replace(
            pg.halo_out, **{f: getattr(pg.halo_out, f)[0] for f in _SHARDED_HALO_FIELDS}
        ),
        **sq,
    )


def _make_sharded_fn(pg: PartitionedGraph, mesh, field_keys, make_local_fn):
    """jit(shard_map(...)) wrapper shared by step and stop dispatches.

    ``make_local_fn(pgl, comm)`` returns the per-shard ``fields → fields``
    function; this owns all the plumbing (specs, block squeeze/unsqueeze)
    so it cannot diverge between the two dispatch kinds.
    """
    from jax.experimental.shard_map import shard_map

    fspec = {k: P(AXIS) for k in field_keys}

    def body(flds, pgb):
        pgl = _local_view(pgb)
        comm = ShardComm(pgl)
        local = {k: v[0] for k, v in flds.items()}
        new = make_local_fn(pgl, comm)(local)
        return {k: v[None] for k, v in new.items()}

    return jax.jit(
        shard_map(
            body, mesh=mesh, in_specs=(fspec, pg_partition_specs(pg)),
            out_specs=fspec, check_rep=False,
        )
    )


def _make_step_fn(
    step: ast.Step, plan: StepPlan, pg: PartitionedGraph, mesh, field_keys
):
    return _make_sharded_fn(
        pg, mesh, field_keys,
        lambda pgl, comm: StepExecutor(step, pgl, comm=comm, plan=plan),
    )


def _make_stop_fn(stop: ast.StopStep, pg: PartitionedGraph, mesh, field_keys):
    return _make_sharded_fn(
        pg, mesh, field_keys,
        lambda pgl, comm: make_stop_fn(stop, pgl, comm=comm),
    )


# ---------------------------------------------------------------------------
# the runtime


def run_bsp_partitioned(
    prog: ast.Prog,
    graph,
    fields: Dict[str, jax.Array],
    schedule: str = "pull",
    max_iters: int = 100_000,
    mesh=None,
    n_shards: int = None,
    byte_costs: Optional[ByteCostModel] = None,
) -> BSPResult:
    """Execute a Palgol program over partitioned vertex state.

    Same contract as :func:`repro.pregel.runtime.run_bsp` (canonical field
    dict in, final *dense* fields + superstep count + trips out); the graph
    is partitioned over ``mesh`` (default: a 1-D mesh over all local
    devices, built by :func:`repro.dist.sharding.shard_mesh`). Every
    schedule runs here: ``"pull"`` (pointer-doubled gather_global rounds),
    ``"push"`` (the paper's request/combined-reply rounds — gather_global's
    owner-bucketed request exchange is the combined request set),
    ``"naive"`` (one gather_global per chain hop — the honest request/reply
    wire cost), ``"auto"`` (cheapest per step by plan op count, or by the
    byte model when ``byte_costs`` is given — build one from this layout
    with :func:`repro.graph.partition.byte_cost_model`).
    """
    from repro.dist import sharding as shd

    if mesh is None:
        mesh = shd.shard_mesh(n_shards)
    n_shards = mesh.shape[AXIS]
    pg = partition_graph(graph, n_shards)
    fields = {k: jnp.asarray(v) for k, v in fields.items()}
    if HALTED not in fields:
        fields[HALTED] = jnp.zeros((graph.n_vertices,), jnp.bool_)
    pfields = partition_fields(pg, fields)
    pfields = jax.device_put(
        pfields, shd.vertex_partition_shardings(pfields, mesh)
    )
    pg = jax.device_put(pg, shd.vertex_partition_shardings(pg, mesh))

    counter = [0]
    trips: List[int] = []
    cache: Dict[int, tuple] = {}
    keys = tuple(sorted(pfields))

    def exec_step(step: ast.Step, flds):
        if id(step) not in cache:
            plan = lower_step(step, schedule=schedule, byte_costs=byte_costs)
            cache[id(step)] = (
                _make_step_fn(step, plan, pg, mesh, keys),
                plan.n_supersteps,
            )
        fn, n_ss = cache[id(step)]
        counter[0] += n_ss
        return fn(flds, pg)

    def exec_stop(stop: ast.StopStep, flds):
        if id(stop) not in cache:
            cache[id(stop)] = (_make_stop_fn(stop, pg, mesh, keys), 1)
        fn, n_ss = cache[id(stop)]
        counter[0] += n_ss
        return fn(flds, pg)

    out = walk_program(
        prog, pfields, exec_step, exec_stop, counter, trips, max_iters
    )
    return BSPResult(
        fields=unpartition_fields(pg, out),
        supersteps=counter[0],
        trips=trips,
    )
