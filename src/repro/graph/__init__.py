"""Graph substrate: dense, statically-shaped graph representation + segment ops.

This layer is shared by the Pregel runtime (``repro.pregel``), the Palgol
compiler's generated code (``repro.core.codegen``), and the GNN model zoo
(``repro.models.gnn``). Everything here is pure JAX and jit/pjit friendly.
"""

from repro.graph.structure import Graph, from_edge_list, symmetrize, pad_edges
from repro.graph.ops import (
    segment_reduce,
    gather,
    scatter_combine,
    edge_softmax,
    out_degrees,
    in_degrees,
    COMBINE_IDENTITY,
)

__all__ = [
    "Graph",
    "from_edge_list",
    "symmetrize",
    "pad_edges",
    "segment_reduce",
    "gather",
    "scatter_combine",
    "edge_softmax",
    "out_degrees",
    "in_degrees",
    "COMBINE_IDENTITY",
]
