"""Dense, statically-shaped graph representation.

Pregel stores per-vertex adjacency lists; on TPU we use a struct-of-arrays
sorted-COO layout (``src``, ``dst``, ``weight``) padded to a static edge count,
plus an explicit validity mask. Edges are stored sorted by ``dst`` so that
"receive messages along incoming edges" is a sorted segment reduction (the
MXU-friendly hot path); the transpose ordering (sorted by ``src``) is
maintained lazily for algorithms that push along outgoing edges.

Conventions
-----------
* An edge ``(src[i], dst[i])`` means: ``dst[i]`` can *pull* data from
  ``src[i]`` (i.e. ``src[i]`` is an in-neighbor of ``dst[i]``). For Palgol's
  ``In[v]`` the neighbor id ``e.id`` is ``src``; for ``Out[v]`` we use the
  transposed arrays; for undirected ``Nbr[v]`` the edge list must be
  symmetric (see :func:`symmetrize`) and ``In``/``Out`` coincide.
* Padding edges carry ``src = dst = n_vertices`` (an out-of-range sentinel)
  and ``mask = False``. All consumers either segment-reduce with explicit
  ``num_segments=n_vertices`` (sentinel rows are dropped by scatter's
  ``mode="drop"``) or mask messages to the combiner identity first.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Graph:
    """Static-shape graph. ``n_vertices``/``n_edges`` are trace-static."""

    # --- data (pytree leaves) ---
    src: jax.Array  # i32[E]  edge source, sorted by dst
    dst: jax.Array  # i32[E]  edge destination (ascending)
    weight: jax.Array  # f32[E] edge weight (1.0 if unweighted)
    edge_mask: jax.Array  # bool[E] False on padding rows
    # transpose ordering (sorted by src) for push-style traversal
    t_src: jax.Array  # i32[E]
    t_dst: jax.Array  # i32[E]
    t_weight: jax.Array  # f32[E]
    t_mask: jax.Array  # bool[E]

    # --- static metadata ---
    n_vertices: int = dataclasses.field(metadata=dict(static=True))
    n_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def sentinel(self) -> int:
        return self.n_vertices

    def in_edges(self):
        """(neighbor_id, self_id, weight, mask) for pull-along-In traversal."""
        return self.src, self.dst, self.weight, self.edge_mask

    def out_edges(self):
        """(neighbor_id, self_id, weight, mask) for traversal of Out[v].

        For ``Out[v]`` the "current vertex" is the edge *source*; the
        neighbor (``e.id``) is the destination. We return the transposed
        arrays so the segment key (second element) is sorted.
        """
        return self.t_dst, self.t_src, self.t_weight, self.t_mask

    def edges(self, direction: str):
        if direction in ("in", "nbr"):
            return self.in_edges()
        if direction == "out":
            return self.out_edges()
        raise ValueError(f"unknown edge direction {direction!r}")


def _sort_by(key: np.ndarray, *arrays: np.ndarray):
    order = np.argsort(key, kind="stable")
    return tuple(a[order] for a in arrays)


def from_edge_list(
    src,
    dst,
    n_vertices: int,
    weight=None,
    pad_to: Optional[int] = None,
) -> Graph:
    """Build a :class:`Graph` from host-side edge arrays.

    This runs on host (numpy) at graph-construction time; the result is a
    pytree of device arrays. ``pad_to`` rounds the edge count up to a static
    size (useful to keep recompilation away when streaming graphs).
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if weight is None:
        weight = np.ones(src.shape, dtype=np.float32)
    weight = np.asarray(weight, dtype=np.float32)
    if src.shape != dst.shape or src.shape != weight.shape:
        raise ValueError("src/dst/weight must have identical shapes")
    if src.ndim != 1:
        raise ValueError("edge arrays must be rank-1")
    e = src.shape[0]
    n_edges = int(pad_to) if pad_to is not None else e
    if n_edges < e:
        raise ValueError(f"pad_to={pad_to} smaller than edge count {e}")

    sentinel = n_vertices
    pad = n_edges - e
    src_p = np.concatenate([src, np.full((pad,), sentinel, np.int32)])
    dst_p = np.concatenate([dst, np.full((pad,), sentinel, np.int32)])
    w_p = np.concatenate([weight, np.zeros((pad,), np.float32)])
    mask_p = np.concatenate([np.ones((e,), bool), np.zeros((pad,), bool)])

    # pull ordering: sorted by dst
    dst_s, src_s, w_s, m_s = _sort_by(dst_p, dst_p, src_p, w_p, mask_p)
    # push ordering: sorted by src
    tsrc_s, tdst_s, tw_s, tm_s = _sort_by(src_p, src_p, dst_p, w_p, mask_p)

    return Graph(
        src=jnp.asarray(src_s),
        dst=jnp.asarray(dst_s),
        weight=jnp.asarray(w_s),
        edge_mask=jnp.asarray(m_s),
        t_src=jnp.asarray(tsrc_s),
        t_dst=jnp.asarray(tdst_s),
        t_weight=jnp.asarray(tw_s),
        t_mask=jnp.asarray(tm_s),
        n_vertices=int(n_vertices),
        n_edges=int(n_edges),
    )


def symmetrize(src, dst, weight=None):
    """Host-side: return the symmetric closure of an edge list (deduplicated).

    Palgol's ``Nbr`` field assumes every undirected edge is stored on both
    endpoints; the compiler relies on this symmetry (paper §3.2).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if weight is None:
        weight = np.ones(src.shape, dtype=np.float32)
    weight = np.asarray(weight, dtype=np.float32)
    a = np.concatenate([src, dst])
    b = np.concatenate([dst, src])
    w = np.concatenate([weight, weight])
    # dedup parallel edges, keep first weight
    key = a * (max(int(b.max(initial=0)) + 1, 1)) + b
    _, idx = np.unique(key, return_index=True)
    idx.sort()
    return a[idx].astype(np.int32), b[idx].astype(np.int32), w[idx]


def pad_edges(graph: Graph, n_edges: int) -> Graph:
    """Re-pad a graph to a larger static edge count (host-side)."""
    if n_edges < graph.n_edges:
        raise ValueError("cannot shrink edge array")
    src = np.asarray(graph.src)
    dst = np.asarray(graph.dst)
    w = np.asarray(graph.weight)
    m = np.asarray(graph.edge_mask)
    keep = m
    return from_edge_list(
        src[keep], dst[keep], graph.n_vertices, w[keep], pad_to=n_edges
    )
