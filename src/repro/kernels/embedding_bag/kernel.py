"""EmbeddingBag kernel (TPU Pallas, scalar-prefetch row gather).

The recsys lookup hot path: bags of ``H`` indices into a huge ``[V, D]``
table, sum-reduced per bag. JAX has no native EmbeddingBag; the XLA fallback
is gather + reshape + reduce. This kernel instead uses
``PrefetchScalarGridSpec``: the (small) index array is prefetched to SMEM,
and each grid step's BlockSpec ``index_map`` *reads the prefetched index* to
stream exactly one table row HBM→VMEM — no [B, H, D] gather intermediate is
ever materialized, and rows for the next step are double-buffered by the
Pallas pipeline while the current row accumulates.

Grid: (B, H), bag dim outer, hot-index dim inner; a [1, D] f32 scratch
accumulates across H and writes the bag's output row once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(idx_ref, w_ref, table_ref, o_ref, acc_ref, *, n_hot):
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    b = pl.program_id(0)
    weight = w_ref[b, h]
    acc_ref[...] += table_ref[...].astype(jnp.float32) * weight

    @pl.when(h == n_hot - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def embedding_bag_kernel(
    table: jax.Array,  # [V, D]
    indices: jax.Array,  # [B, H] int32
    weights: jax.Array,  # [B, H] (0.0 masks a slot)
    interpret: bool = False,
) -> jax.Array:
    b, h = indices.shape
    v, d = table.shape
    kernel = functools.partial(_bag_kernel, n_hot=h)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1, h), lambda i, j, idx: (i, 0)),  # weights row
            pl.BlockSpec(  # one table row, chosen by the prefetched index
                (1, d), lambda i, j, idx: (idx[i, j], 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, idx: (i, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(indices, weights, table)
