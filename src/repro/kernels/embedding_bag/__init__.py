from repro.kernels.embedding_bag.ops import embedding_bag_pallas

__all__ = ["embedding_bag_pallas"]
