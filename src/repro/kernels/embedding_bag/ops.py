"""jit'd wrapper: lane padding + default weights for the bag kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_pallas(
    table: jax.Array,  # [V, D]
    indices: jax.Array,  # [B, H]
    weights=None,  # [B, H] or None
    mask=None,  # [B, H] bool or None
    interpret: bool = False,
) -> jax.Array:
    v, d = table.shape
    b, h = indices.shape
    if weights is None:
        weights = jnp.ones((b, h), table.dtype)
    if mask is not None:
        weights = weights * mask.astype(weights.dtype)
    # lane-pad D to a multiple of 128 (TPU VMEM tile width)
    pd = (-d) % 128
    if pd:
        table = jnp.pad(table, ((0, 0), (0, pd)))
    out = embedding_bag_kernel(
        table, indices.astype(jnp.int32), weights.astype(table.dtype),
        interpret=interpret,
    )
    return out[:, :d]
