"""Pure-jnp oracle for the embedding_bag kernel."""

import jax.numpy as jnp


def embedding_bag_ref(table, indices, weights):
    vals = jnp.take(table, indices, axis=0, mode="clip")  # [B, H, D]
    return jnp.sum(vals * weights[..., None].astype(vals.dtype), axis=1)
