from repro.kernels.gather_rows.ops import gather_rows_pallas

__all__ = ["gather_rows_pallas"]
