"""Pure-jnp oracle for gather_rows."""

import jax.numpy as jnp


def gather_rows_ref(table, idx):
    return jnp.take(table, idx, axis=0, mode="clip")
