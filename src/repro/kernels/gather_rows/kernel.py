"""Row-gather kernel (TPU Pallas, scalar-prefetch).

The Palgol chain-access primitive: ``out[i] = table[idx[i]]``. One grid step
per output block row; the BlockSpec index_map reads the prefetched index so
the pipeline streams exactly the referenced rows HBM→VMEM (one-sided remote
read — the pull-mode schedule of core/logic.py at the kernel level).
"""

from __future__ import annotations

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gather_kernel(idx_ref, row_ref, o_ref):
    o_ref[...] = row_ref[...]


def gather_rows_kernel(
    table: jax.Array,  # [V, D]
    idx: jax.Array,  # [N] int32
    interpret: bool = False,
) -> jax.Array:
    n = idx.shape[0]
    v, d = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, d), lambda i, idx: (idx[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, idx: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), table.dtype),
        interpret=interpret,
    )(idx, table)
