"""jit'd wrapper for gather_rows (lane padding + clipping)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gather_rows.kernel import gather_rows_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_rows_pallas(table, idx, interpret: bool = False):
    v, d = table.shape
    pd = (-d) % 128
    if pd:
        table = jnp.pad(table, ((0, 0), (0, pd)))
    idx = jnp.clip(idx.astype(jnp.int32), 0, v - 1)
    out = gather_rows_kernel(table, idx, interpret=interpret)
    return out[:, :d]
