"""FlashAttention forward kernel (TPU Pallas).

Layout: q [BH, Sq, D], k/v [BHkv, Sk, D] (heads flattened into the leading
dim; the ops wrapper transposes from the model's [B, S, H, D]).

Grid: (BH, Sq/bq, Sk/bk) — the KV dimension is innermost (sequential), so
the online-softmax state (m, l, acc) lives in VMEM scratch across KV steps
and the output block is written once on the last KV step. Causal and
sliding-window masks are applied from block-relative iota positions; fully
masked blocks skip the matmuls entirely (``pl.when``), which on TPU skips
the HBM→VMEM prefetch of the dead block too.

VMEM working set per step: bq·D (q) + 2·bk·D (k,v) + bq·bk (scores)
+ bq·(D+2) f32 scratch — with bq=bk=512, D=128, bf16: ~0.9 MB, well inside
the ~16 MB VMEM budget, leaving room for double buffering. Both matmuls
contract over 128-multiples (MXU-aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _attn_kernel(
    q_ref,  # [1, bq, D]
    k_ref,  # [1, bk, D]
    v_ref,  # [1, bk, D]
    o_ref,  # [1, bq, D]
    acc_ref,  # [bq, D] f32 scratch
    m_ref,  # [bq, 128] f32 scratch (lane-padded)
    l_ref,  # [bq, 128] f32 scratch
    *,
    causal: bool,
    window,
    bq: int,
    bk: int,
    n_k: int,
    sk_valid: int,
):
    i = pl.program_id(1)  # q block
    j = pl.program_id(2)  # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = i * bq
    k_start = j * bk
    # block-level reachability: skip fully-masked blocks
    live = True
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0]  # [bq, D]
        k = k_ref[0]  # [bk, D]
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [bq, bk]
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos < sk_valid  # padding KVs
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window is not None:
            ok = jnp.logical_and(ok, qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [bq, D]
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == n_k - 1)
    def _finalize():
        lsum = l_ref[:, 0]
        norm = jnp.where(lsum > 0.0, 1.0 / jnp.maximum(lsum, 1e-30), 0.0)
        o_ref[0] = (acc_ref[...] * norm[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # [BH, Sq, D]
    k: jax.Array,  # [BHkv, Sk, D]
    v: jax.Array,
    *,
    causal: bool = True,
    window=None,
    sk_valid: int = 0,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    from jax.experimental.pallas import tpu as pltpu

    bh, sq, d = q.shape
    bhkv, sk, _ = k.shape
    n_rep = bh // bhkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    n_k = sk // bk
    sk_valid = sk_valid or sk

    grid = (bh, sq // bq, n_k)
    kernel = functools.partial(
        _attn_kernel,
        causal=causal,
        window=window,
        bq=bq,
        bk=bk,
        n_k=n_k,
        sk_valid=sk_valid,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, _n=n_rep: (h // _n, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, _n=n_rep: (h // _n, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
