"""Pure-jnp oracle for the flash_attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,
    causal: bool = True,
    window=None,
    sk_valid=None,
) -> jax.Array:
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    n_rep = h // hkv
    k = jnp.repeat(k, n_rep, axis=1)
    v = jnp.repeat(v, n_rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32)
    sk = k.shape[2]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if sk_valid is not None:
        ok &= kpos < sk_valid
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= (qpos - kpos) < window
    s = jnp.where(ok[None, None], s, -jnp.inf)
    # rows with no valid keys produce 0 (matching the kernel's l==0 guard)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(q.dtype), v)
