"""jit'd wrapper: model layout + padding + GQA for the flash kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,
    causal: bool = True,
    window=None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(sk, 8))
    pq = (-sq) % bq
    pk = (-sk) % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention_fwd(
        qp.reshape(b * h, sq + pq, d),
        kp.reshape(b * hkv, sk + pk, d),
        vp.reshape(b * hkv, sk + pk, d),
        causal=causal,
        window=window,
        sk_valid=sk,
        block_q=bq,
        block_k=bk,
        interpret=interpret,
    )
    return out.reshape(b, h, sq + pq, d)[:, :, :sq]
