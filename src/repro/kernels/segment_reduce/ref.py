"""Pure-jnp oracle for the segment_reduce kernel."""

import jax
import jax.numpy as jnp


def segment_sum_ref(values, segment_ids, num_segments, mask=None):
    if mask is not None:
        values = jnp.where(mask[:, None], values, 0)
    return jax.ops.segment_sum(values, segment_ids, num_segments=num_segments)
