from repro.kernels.segment_reduce.ops import segment_sum_ell

__all__ = ["segment_sum_ell"]
