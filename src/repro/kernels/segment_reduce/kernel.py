"""Blocked-ELL segment-sum kernel (TPU Pallas).

This is the Pregel message combiner / GNN aggregation hot spot, adapted to
the TPU's strengths: instead of a scatter (serialized on TPU), the edges are
pre-bucketed so that all messages destined to segment block ``t`` live in
edge-slot range ``[t·budget, (t+1)·budget)``, and the kernel reduces each
bucket with **one-hot matmuls on the MXU**:

    out[t·nb : (t+1)·nb, :] = Σ_j onehot(local_dst_j)ᵀ @ vals_j

Grid: (T, budget/eb) with the edge dim innermost; a [nb, D] f32 VMEM scratch
accumulates partial sums across edge sub-blocks, written out once.

Padding slots carry local id = nb (one-hot row of zeros ⇒ no contribution).
VMEM per step: eb·D (vals) + eb (ids) + nb·eb (one-hot) + nb·D (scratch);
with eb=256, nb=256, D=128, f32: ~0.5 MB.

The one-hot matmul costs 2·eb·nb·D flops vs the scatter's eb·D — a
deliberate flops-for-regularity trade: on TPU the MXU delivers those flops
at peak while a scatter bottlenecks on serialized VREG updates. See
EXPERIMENTS.md §Perf for the roofline view.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_kernel(ids_ref, vals_ref, o_ref, acc_ref, *, nb, eb, n_e):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ids = ids_ref[...]  # [eb] local ids in [0, nb]; nb == padding
    vals = vals_ref[...]  # [eb, D]
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (nb, eb), 0) == ids[None, :]
    ).astype(vals.dtype)
    acc_ref[...] += jax.lax.dot_general(
        onehot, vals, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == n_e - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def segment_sum_ell_kernel(
    ids: jax.Array,  # [T * budget] local ids (dst - t*nb; padding = nb)
    vals: jax.Array,  # [T * budget, D] bucketed messages
    *,
    n_blocks: int,
    nb: int,
    budget: int,
    eb: int = 256,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    d = vals.shape[1]
    eb = min(eb, budget)
    assert budget % eb == 0
    n_e = budget // eb
    out_dtype = out_dtype or vals.dtype
    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(_segsum_kernel, nb=nb, eb=eb, n_e=n_e)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks, n_e),
        in_specs=[
            pl.BlockSpec((eb,), lambda t, j, _n=n_e: (t * _n + j,)),
            pl.BlockSpec((eb, d), lambda t, j, _n=n_e: (t * _n + j, 0)),
        ],
        out_specs=pl.BlockSpec((nb, d), lambda t, j: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * nb, d), out_dtype),
        scratch_shapes=[pltpu.VMEM((nb, d), jnp.float32)],
        interpret=interpret,
    )(ids, vals)
