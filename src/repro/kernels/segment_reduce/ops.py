"""Wrapper: edge bucketing (blocked-ELL layout) + overflow fallback.

``segment_sum_ell(values, segment_ids, num_segments)``:

1. host/jnp preprocessing sorts edges by destination block and scatters them
   into per-block slot ranges of a fixed ``budget`` (rounded to the edge
   sub-block size). For power-law graphs the budget is set from the max
   block load; the overflow path (when a cap is given) falls back to
   ``jax.ops.segment_sum`` for the spilled edges and adds the two partial
   results — Pregel's combiner semantics make this trivially correct.
2. the Pallas kernel reduces each bucket with MXU one-hot matmuls.

The bucketing permutation is graph-structure-only, so in training it is
computed once per graph and reused every step (amortized to zero), exactly
like the CSR sort in any production GNN system.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce.kernel import segment_sum_ell_kernel


def build_ell_layout(
    segment_ids: jax.Array,
    num_segments: int,
    nb: int = 256,
    eb: int = 256,
    budget_cap: Optional[int] = None,
):
    """Compute (slot permutation, budget, n_blocks) for the ELL layout.

    Returns ``slots[e]``: the flat slot index each edge lands in (or an
    out-of-range spill sentinel when ``budget_cap`` truncates), plus the
    layout dims. jnp-traceable, but intended to be computed once per graph.
    """
    n_blocks = -(-num_segments // nb)
    blk = segment_ids // nb  # [E]
    order = jnp.argsort(blk)
    sorted_blk = blk[order]
    counts = jnp.bincount(blk, length=n_blocks)
    budget = int(counts.max()) if not isinstance(counts, jax.core.Tracer) else 0
    # rank of each edge within its block
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]]
    )
    rank_sorted = jnp.arange(blk.shape[0]) - starts[sorted_blk]
    rank = jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)
    if budget_cap is not None:
        budget = min(budget, budget_cap) if budget else budget_cap
    budget = max(-(-budget // eb) * eb, eb)
    spill = rank >= budget
    slots = jnp.where(spill, n_blocks * budget, blk * budget + rank)
    return slots, int(budget), int(n_blocks), spill


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_segments", "nb", "eb", "budget", "n_blocks", "interpret",
    ),
)
def _run(values, segment_ids, slots, spill, num_segments, nb, eb, budget,
         n_blocks, interpret):
    d = values.shape[1]
    local = jnp.where(spill, nb, segment_ids % nb).astype(jnp.int32)
    ids_b = jnp.full((n_blocks * budget,), nb, jnp.int32)
    vals_b = jnp.zeros((n_blocks * budget, d), values.dtype)
    ids_b = ids_b.at[slots].set(local, mode="drop")
    vals_b = vals_b.at[slots].set(values, mode="drop")
    out = segment_sum_ell_kernel(
        ids_b, vals_b, n_blocks=n_blocks, nb=nb, budget=budget, eb=eb,
        out_dtype=values.dtype, interpret=interpret,
    )[:num_segments]
    # spilled edges (over-budget) go through the XLA combiner and merge in —
    # Pregel's accumulative-write semantics make the split trivially correct
    spilled_vals = jnp.where(spill[:, None], values, 0)
    out = out + jax.ops.segment_sum(
        spilled_vals, segment_ids, num_segments=num_segments
    )
    return out


def segment_sum_ell(
    values: jax.Array,  # [E, D]
    segment_ids: jax.Array,  # [E]
    num_segments: int,
    mask: Optional[jax.Array] = None,
    nb: int = 256,
    eb: int = 256,
    budget_cap: Optional[int] = None,
    interpret: bool = False,
) -> jax.Array:
    """Drop-in replacement for masked segment-sum on TPU."""
    if mask is not None:
        segment_ids = jnp.where(mask, segment_ids, num_segments)
        values = jnp.where(mask[:, None], values, 0)
    # route padding/masked edges to a ghost block, then slice it away
    n_seg_pad = num_segments + 1
    slots, budget, n_blocks, spill = build_ell_layout(
        segment_ids, n_seg_pad, nb=nb, eb=eb, budget_cap=budget_cap
    )
    out = _run(
        values, segment_ids, slots, spill, n_seg_pad, nb, eb, budget,
        n_blocks, interpret,
    )
    return out[:num_segments]
