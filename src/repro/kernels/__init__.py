"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package has three files:
  kernel.py — ``pl.pallas_call`` body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd wrapper (padding, layout, fallback paths)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels (all validated in interpret mode on CPU; TPU is the target):
  flash_attention — online-softmax attention (causal/SWA/GQA), the hot spot
                    of every LM cell;
  segment_reduce  — blocked-ELL one-hot-matmul segment sum: the Pregel
                    message combiner / GNN aggregation hot spot, recast as
                    MXU matmuls instead of scatters (the paper's combiner
                    concept, §4.4, in TPU form);
  embedding_bag   — scalar-prefetch gather-reduce over huge vocab tables
                    (recsys lookup hot path);
  gather_rows     — chain-access row gather (Palgol remote reads).
"""
