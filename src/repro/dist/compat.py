"""Version compatibility shims for the distribution layer.

The codebase (and the dry-run/test harness) targets the modern mesh API:

* ``jax.make_mesh(shape, axes, axis_types=(AxisType.Auto, ...))``
* ``jax.sharding.AxisType``

Older jaxlib builds (< 0.4.38) predate ``AxisType`` and the ``axis_types``
keyword. Rather than forking every call site on the jax version, this module
installs the missing pieces *once*, gated on their absence:

* a stand-in ``jax.sharding.AxisType`` enum (all meshes on old jax behave as
  ``Auto`` — GSPMD propagation — which is exactly the semantics every caller
  here requests);
* a ``jax.make_mesh`` wrapper that accepts and drops ``axis_types``.

Importing :mod:`repro.dist` (or any of its consumers) applies the shims, so
subprocess tests that call ``jax.make_mesh(..., axis_types=...)`` directly
keep working on both old and new jax. On a jax that already provides the
API, this module is a no-op.
"""

from __future__ import annotations

import enum
import inspect

import jax


def install() -> None:
    """Install the mesh-API shims if (and only if) jax lacks them."""
    if not hasattr(jax.sharding, "AxisType"):

        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    try:
        params = inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtins w/o sig
        params = {}
    if "axis_types" not in params:
        _orig_make_mesh = jax.make_mesh

        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            # pre-AxisType jax meshes behave as Auto (GSPMD propagation);
            # refuse loudly rather than silently degrade other semantics
            auto = jax.sharding.AxisType.Auto
            if axis_types is not None and any(
                t is not auto for t in axis_types
            ):
                raise NotImplementedError(
                    f"axis_types={axis_types} requires jaxlib >= 0.4.38; "
                    "this jax only supports Auto-typed meshes"
                )
            if devices is not None:
                return _orig_make_mesh(axis_shapes, axis_names,
                                       devices=devices)
            return _orig_make_mesh(axis_shapes, axis_names)

        make_mesh.__doc__ = _orig_make_mesh.__doc__
        jax.make_mesh = make_mesh


install()
