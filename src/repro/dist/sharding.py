"""Sharding rules and the active-mesh context — the distribution layer.

This is the single place where logical parallelism decisions live; models
never name mesh axes directly for *data* parallelism, they tag dimensions
with the logical axes below and the rules here map them onto whatever mesh
is active (or no-op entirely when none is — smoke tests, oracles, CPU CI).

Physical mesh axes (see ``repro.launch.mesh``):

* ``pod``    — inter-pod data parallelism (multi-pod production mesh only);
* ``data``   — intra-pod data parallelism / FSDP shard axis;
* ``model``  — tensor/expert parallelism.

Logical axes:

* :data:`BATCH` — the data-parallel group (``pod`` × ``data``): batch dims
  of activations, token streams, KV caches;
* :data:`ALL`   — every mesh axis flattened: the edge/node dimension of
  graph workloads, where the mesh is one big 1-D partition (vertex-cut with
  replicated vertex state — see ``repro.graph.ops``).

Every spec derivation routes through :func:`_maybe`, which drops a mesh
axis from a dimension that it does not evenly divide (GSPMD would reject
the constraint; padding to divisibility is the caller's optimization, not a
correctness requirement).

Param-spec policy (``lm_param_spec``, keyed by param path):

=====================  ======================  ===========================
path                   shape                   spec (fsdp mode)
=====================  ======================  ===========================
``embed``/``unembed``  ``[V, D]``              ``P("model", "data")``
``layers/wq|wk|wv``    ``[L, D, H·hd]``        ``P(None, "data", "model")``
``layers/wo``          ``[L, H·hd, D]``        ``P(None, "model", "data")``
``layers/ffn/w1|w3``   ``[L, D, F]``           ``P(None, "data", "model")``
``layers/ffn/w2``      ``[L, F, D]``           ``P(None, "model", "data")``
``layers/moe/w*``      ``[L, E, D, F]``        ``P(None, "model", "data", None)``
``layers/moe/router``  ``[L, D, E]``           ``P()``  (fp32, tiny — keep
                                               routing bit-identical)
norms / biases         ``[L, D]`` / ``[D]``    ``P()``
=====================  ======================  ===========================

i.e. the *parallel* matmul dim (heads / ffn / experts) shards over
``model`` and the reduction dim shards over ``data`` (FSDP); ``zero1``
mode keeps only the ``model`` shards on the stored params (the optimizer
state keeps the full 2-D sharding — pass ``mode="fsdp"`` for it).
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist import compat  # noqa: F401  (installs jax mesh-API shims)

# --------------------------------------------------------------------------
# logical axes

ALL = "__all__"  #: every mesh axis, flattened (graph edge/node dims)
BATCH = "__batch__"  #: the data-parallel group (pod × data)
SHARD = "shard"  #: the 1-D vertex-partition axis (``repro.graph.partition``)

#: physical axes belonging to the data-parallel group, in mesh order
_DATA_AXES = ("pod", "data")
#: every physical axis this layer knows about, in mesh order
_MESH_AXES = ("pod", "data", "model")

_AxisEntry = Union[None, str, Tuple[str, ...]]


# --------------------------------------------------------------------------
# active mesh context

_ACTIVE_MESH: Optional[Mesh] = None


def activate(mesh: Mesh) -> Mesh:
    """Make ``mesh`` the process-wide active mesh.

    ``constrain`` (and the mesh-aware dispatch in ``repro.graph.ops`` /
    ``repro.models.transformer.moe``) consult this; with no active mesh
    they all degrade to their single-device reference paths.
    """
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh
    return mesh


def deactivate() -> None:
    """Clear the active mesh (idempotent)."""
    global _ACTIVE_MESH
    _ACTIVE_MESH = None


def active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


# --------------------------------------------------------------------------
# axis resolution helpers


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Physical axes of the data-parallel group present on ``mesh``."""
    return tuple(a for a in _DATA_AXES if a in mesh.shape)


def all_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Every known physical axis present on ``mesh``, in mesh order."""
    return tuple(a for a in _MESH_AXES if a in mesh.shape)


def _collapse(entry: Sequence[str]) -> _AxisEntry:
    """() → None, (a,) → a, (a, b, ...) → tuple (PartitionSpec idiom)."""
    entry = tuple(entry)
    if not entry:
        return None
    if len(entry) == 1:
        return entry[0]
    return entry


def _resolve(axes: Sequence[Any], mesh: Mesh) -> Tuple[_AxisEntry, ...]:
    """Map logical entries (ALL / BATCH) to physical axis entries."""
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        elif a == ALL:
            out.append(_collapse(all_axes(mesh)))
        elif a == BATCH:
            out.append(_collapse(data_axes(mesh)))
        else:
            out.append(a if isinstance(a, tuple) else str(a))
    return tuple(out)


def axis_size(entry: _AxisEntry, mesh: Mesh) -> int:
    """Product of mesh-axis sizes named by ``entry`` (1 for ``None``)."""
    if entry is None:
        return 1
    names = entry if isinstance(entry, tuple) else (entry,)
    n = 1
    for name in names:
        n *= mesh.shape[name]
    return n


def _maybe(
    axes: Sequence[_AxisEntry], shape: Sequence[int], mesh: Mesh
) -> P:
    """PartitionSpec over ``axes``, dropping entries that cannot apply.

    An entry is kept only if every named axis exists on ``mesh`` and the
    product of their sizes evenly divides the corresponding dimension;
    otherwise that dimension falls back to replication. Entries beyond
    ``len(shape)`` are truncated (a spec longer than the array rank is
    rejected by ``with_sharding_constraint``). This is what makes every
    rule in this module total: an indivisible (arch, mesh) pair degrades
    gracefully instead of failing to lower.
    """
    out = []
    for i, entry in enumerate(axes[: len(shape)]):
        if entry is None:
            out.append(None)
            continue
        names = entry if isinstance(entry, tuple) else (entry,)
        if any(name not in mesh.shape for name in names):
            out.append(None)
            continue
        if shape[i] % axis_size(entry, mesh) != 0:
            out.append(None)
            continue
        out.append(entry)
    return P(*out)


def constrain(x: jax.Array, axes: Sequence[Any]) -> jax.Array:
    """``with_sharding_constraint`` against the active mesh; no-op without.

    ``axes`` is one entry per dimension: ``None`` (replicated), a physical
    axis name, a tuple of names, or a logical axis (:data:`ALL`,
    :data:`BATCH`). Indivisible entries are dropped per :func:`_maybe`, so
    ``constrain`` is always safe to call on oddly-shaped values.
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    spec = _maybe(_resolve(axes, mesh), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# parameter sharding rules (path-keyed)

#: param names that are always replicated (norm gains, biases, scalars)
_REPLICATED_NAMES = frozenset(
    {"ln1", "ln2", "ln_f", "q_norm", "k_norm", "bq", "bk", "bv", "b",
     "router", "step"}
)
#: column-parallel matmuls: reduction dim → data (FSDP), output dim → model
_COL_PARALLEL = frozenset({"wq", "wk", "wv", "w1", "w3"})
#: row-parallel matmuls: input dim → model, output dim → data (FSDP)
_ROW_PARALLEL = frozenset({"wo", "w2"})


def _drop_data(spec: P) -> P:
    """zero1 mode: strip the data-group axes (params stay model-sharded)."""

    def strip(entry):
        if entry is None:
            return None
        names = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(n for n in names if n not in _DATA_AXES)
        return _collapse(kept)

    return P(*(strip(e) for e in spec))


def lm_param_spec(path: str, leaf, mesh: Mesh, mode: str = "fsdp") -> P:
    """Sharding spec for one LM param, keyed by its ``/``-joined path.

    ``leaf`` needs only ``.shape`` (arrays and ShapeDtypeStructs both
    work). See the module docstring for the policy table.
    """
    if mode not in ("fsdp", "zero1"):
        raise ValueError(f"unknown param mode {mode!r}")
    shape = leaf.shape
    name = path.rsplit("/", 1)[-1]
    dat = _collapse(data_axes(mesh))

    if name in _REPLICATED_NAMES or len(shape) <= 1:
        return P()
    if name in ("embed", "unembed"):
        spec = _maybe(("model", dat), shape, mesh)
    elif "moe" in path.split("/") and name in ("w1", "w2", "w3") and len(shape) >= 4:
        # stacked expert weights [L, E, D, F]: experts → model (EP), the
        # next dim → data (FSDP). Same pattern for w2 [L, E, F, D].
        lead = (None,) * (len(shape) - 3)
        spec = _maybe(lead + ("model", dat, None), shape, mesh)
    elif name in _COL_PARALLEL:
        lead = (None,) * (len(shape) - 2)
        spec = _maybe(lead + (dat, "model"), shape, mesh)
    elif name in _ROW_PARALLEL:
        lead = (None,) * (len(shape) - 2)
        spec = _maybe(lead + ("model", dat), shape, mesh)
    else:
        return P()
    if mode == "zero1":
        spec = _drop_data(spec)
    return spec


def gnn_param_spec(path: str, leaf, mesh: Mesh, mode: str = "fsdp") -> P:
    """GNN params are small relative to node/edge state — replicate.

    The parallelism of the graph families lives entirely in the activation
    sharding (:data:`ALL` on node/edge dims) and the shard_map message
    passing; replicated params make every matmul local.
    """
    del path, leaf, mesh, mode
    return P()


def recsys_param_spec(path: str, leaf, mesh: Mesh, mode: str = "fsdp") -> P:
    """RecSys: shard the (huge) embedding tables on vocab, replicate MLP."""
    del mode
    shape = leaf.shape
    name = path.rsplit("/", 1)[-1]
    if "embed" in name and len(shape) >= 2:
        # [n_fields, V, D] (or [V, D]): vocab rows across the whole mesh
        lead = (None,) * (len(shape) - 2)
        return _maybe(lead + (_collapse(all_axes(mesh)), None), shape, mesh)
    return P()


_PARAM_RULES = {
    "lm": lm_param_spec,
    "gnn": gnn_param_spec,
    "recsys": recsys_param_spec,
}


def _path_str(key_path) -> str:
    parts = []
    for k in key_path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:  # pragma: no cover - exotic pytree nodes
            parts.append(str(k))
    return "/".join(parts)


def param_shardings(kind: str, params, mesh: Mesh, mode: str = "fsdp"):
    """Pytree of ``NamedSharding`` matching ``params``, per-family rules.

    ``kind`` ∈ {"lm", "gnn", "recsys"}; ``mode`` ∈ {"fsdp", "zero1"}
    (zero1 is meaningful for "lm" only — stored params keep just their
    ``model`` shards while the optimizer state, requested separately with
    ``mode="fsdp"``, stays fully 2-D sharded).
    """
    rule = _PARAM_RULES[kind]
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: NamedSharding(
            mesh, rule(_path_str(kp), leaf, mesh, mode=mode)
        ),
        params,
    )


# --------------------------------------------------------------------------
# batch / activation shardings


def lm_batch_spec(mesh: Mesh, batch: int) -> P:
    """Spec for a ``[B, ...]`` token-stream array: batch over the DP group."""
    return _maybe((_collapse(data_axes(mesh)),), (batch,), mesh)


def lm_cache_spec(mesh: Mesh, cfg, batch: int, cache: int) -> P:
    """Spec for the stacked KV cache ``[L, B, C, Hkv, hd]``.

    Batch shards over the DP group and the cache *sequence* dim over
    ``model`` (KV sequence parallelism — ``n_kv_heads`` is routinely
    smaller than the model axis, the window length never is), matching the
    per-layer ``constrain`` in ``transformer.model.prefill``.
    """
    shape = (cfg.n_layers, batch, cache, cfg.n_kv_heads, cfg.head_dim)
    return _maybe(
        (None, _collapse(data_axes(mesh)), "model", None, None), shape, mesh
    )


def batch_shardings(kind: str, batch_specs, mesh: Mesh):
    """Pytree of ``NamedSharding`` for model inputs.

    * ``"lm"``: leading (batch) dim over the data-parallel group;
    * ``"gnn"`` / ``"recsys"``: leading (node/edge/batch) dim over *every*
      mesh axis — graph/recsys state is 1-D partitioned across the
      flattened mesh, matching :data:`ALL` constraints in the models.
    """
    entries = {
        "lm": _collapse(data_axes(mesh)),
        "gnn": _collapse(all_axes(mesh)),
        "recsys": _collapse(all_axes(mesh)),
    }
    if kind not in entries:
        raise ValueError(
            f"unknown batch kind {kind!r}; expected one of {sorted(entries)}"
        )
    entry = entries[kind]

    def leaf_sharding(leaf):
        if not getattr(leaf, "shape", ()):  # scalars
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _maybe((entry,), leaf.shape, mesh))

    return jax.tree_util.tree_map(leaf_sharding, batch_specs)


def replicated(x, mesh: Mesh) -> NamedSharding:
    """Fully-replicated sharding (optimizer step counters, scalars)."""
    del x
    return NamedSharding(mesh, P())


# --------------------------------------------------------------------------
# vertex-partition shardings (repro.graph.partition)


def shard_mesh(n_shards: Optional[int] = None, devices=None) -> Mesh:
    """1-D ``("shard",)`` mesh for partitioned vertex state.

    The partitioned Pregel engine flattens whatever devices it is given
    into one shard axis — one contiguous vertex range per device. Defaults
    to every local device; pass ``n_shards`` to use a prefix of them.
    """
    import numpy as np

    devs = list(jax.devices()) if devices is None else list(devices)
    if n_shards is not None:
        if n_shards > len(devs):
            raise ValueError(
                f"n_shards={n_shards} exceeds available devices ({len(devs)})"
            )
        devs = devs[:n_shards]
    return Mesh(np.array(devs), (SHARD,))


def vertex_partition_spec(ndim: int = 2) -> P:
    """Spec for a ``[S, ...]`` per-shard block array: leading dim over
    :data:`SHARD`, everything else replicated."""
    return P(SHARD, *(None,) * (ndim - 1))


def vertex_partition_shardings(tree, mesh: Mesh):
    """Pytree of ``NamedSharding`` for partitioned per-shard arrays.

    Leading dims that the shard axis divides evenly (the ``[S, ...]``
    blocks of a ``PartitionedGraph`` and of partitioned fields) shard over
    :data:`SHARD`; everything else — the ``[S+1]`` owner map, scalars —
    replicates, per the :func:`_maybe` totality rule.
    """

    def leaf_sharding(leaf):
        shape = getattr(leaf, "shape", ())
        if not shape:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, _maybe((SHARD,), shape, mesh))

    return jax.tree_util.tree_map(leaf_sharding, tree)
