"""Distribution layer: mesh compat shims + path-keyed sharding rules.

``repro.dist.sharding`` is the only module that names mesh axes for data
parallelism; everything else tags dimensions with its logical axes
(``ALL``, ``BATCH``) or asks it for param/batch shardings by family.
"""

from repro.dist import compat  # noqa: F401  (installs jax mesh-API shims)
from repro.dist import sharding  # noqa: F401

__all__ = ["compat", "sharding"]
