"""Batched LM serving: prefill + decode with the KV ring buffer.

    PYTHONPATH=src python examples/serve_lm.py --batch 4 --prompt-len 64 \
        --decode-steps 32

Demonstrates the serving path the ``decode_*`` dry-run cells lower:
prefill materializes the window-bounded KV cache, then batched greedy
decode steps stream tokens; reports prefill/decode throughput. The SWA
preset keeps an O(window) cache (the h2o-danube long_500k regime).
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerConfig, model as tm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--swa-window", type=int, default=32)
    args = ap.parse_args()

    cfg = TransformerConfig(
        name="serve-demo", n_layers=4, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=384, vocab_size=2048, d_head=16, swa_window=args.swa_window,
        param_dtype="float32", compute_dtype="float32",
        attn_chunk_q=64, attn_chunk_kv=64,
    )
    params = tm.init(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size,
    )

    prefill = jax.jit(lambda p, t: tm.prefill(p, t, cfg, full_logits=False))
    decode = jax.jit(lambda p, c, t: tm.decode_step(p, c, t, cfg))

    t0 = time.perf_counter()
    logits, cache = jax.block_until_ready(prefill(params, prompts))
    t_prefill = time.perf_counter() - t0
    print(f"prefill: {args.batch}×{args.prompt_len} tokens in "
          f"{t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:,.0f} tok/s); "
          f"KV cache len = {cache['k'].shape[2]} (window-bounded)")

    cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    toks = [cur]
    t0 = time.perf_counter()
    for _ in range(args.decode_steps):
        logits, cache = decode(params, cache, cur)
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        toks.append(cur)
    jax.block_until_ready(cur)
    t_dec = time.perf_counter() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"decode: {args.decode_steps} steps × batch {args.batch} in "
          f"{t_dec*1e3:.1f} ms "
          f"({args.batch*args.decode_steps/t_dec:,.0f} tok/s)")
    print("sampled token ids (first request):", out[0, :16].tolist(), "...")


if __name__ == "__main__":
    main()
