"""Shiloach-Vishkin connectivity — the paper's flagship example (Fig. 6).

    PYTHONPATH=src python examples/connected_components.py

Shows the features Green-Marl/Fregel can't express (paper §5):
* chain access ``D[D[u]]`` — compiled by the logic system (§4.1.1);
* a remote accumulative write ``remote D[D[u]] <?= t``;
and the three execution regimes: fused dense (production), staged BSP with
the pull schedule, staged BSP with the naive request/reply schedule (the
hand-written-code stand-in).
"""

import time

import numpy as np

from repro.core import compile_program
from repro.core import algorithms as alg
from repro.core.logic import pull_rounds, push_rounds
from repro.graph import generators as G
from repro.pregel import run_bsp


def main():
    print("chain-access compilation (paper §4.1.1):")
    for k in (2, 3, 4, 8):
        pat = ("D",) * k
        print(f"  D^{k}[u]: paper push schedule = {push_rounds(pat)} rounds,"
              f" pull schedule = {pull_rounds(pat)} rounds,"
              f" naive request/reply = {2 * (k - 1)} rounds")

    g = G.rmat(11, avg_degree=6, directed=False, seed=3)
    print(f"\ngraph: {g.n_vertices} vertices")
    cp = compile_program(alg.SV, g)

    t0 = time.perf_counter()
    out, trips, counts = cp.run()
    t_fused = time.perf_counter() - t0
    D = np.asarray(out["D"])
    n_components = len(np.unique(D))
    print(f"components: {n_components}; iterations: {trips[0]}")

    f0 = cp.init_fields()
    t0 = time.perf_counter()
    bsp_pull = run_bsp(cp.prog, g, f0, schedule="pull")
    t_pull = time.perf_counter() - t0
    t0 = time.perf_counter()
    # the manual-style baseline keeps the unfused request/reply expansion
    bsp_naive = run_bsp(cp.prog, g, f0, schedule="naive", fuse=False)
    t_naive = time.perf_counter() - t0

    assert np.array_equal(D, np.asarray(bsp_pull.fields["D"]))
    assert np.array_equal(D, np.asarray(bsp_naive.fields["D"]))

    print("\nexecution regimes (identical results):")
    print(f"  fused dense (palgol):   {counts['palgol_push']:3d} supersteps"
          f" (accounted) {t_fused * 1e3:9.1f} ms")
    print(f"  staged BSP, pull:       {bsp_pull.supersteps:3d} supersteps"
          f" (executed)  {t_pull * 1e3:9.1f} ms")
    print(f"  staged BSP, naive:      {bsp_naive.supersteps:3d} supersteps"
          f" (executed)  {t_naive * 1e3:9.1f} ms")
    red = 100 * (1 - counts["palgol_push"] / counts["naive"])
    print(f"\nsuperstep reduction vs naive: {red:.1f}% "
          "(paper reports 46.5–51.7% for S-V)")


if __name__ == "__main__":
    main()
