"""Quickstart: write a Palgol program, compile it, run it on a graph.

    PYTHONPATH=src python examples/quickstart.py

Single-source shortest path (the paper's Fig. 4), end to end:
parse → analyze remote-access patterns → compile to one fused XLA
computation → execute → compare superstep accounting across compilers.
"""

import numpy as np

from repro.core import compile_program, interpret
from repro.core import algorithms as alg
from repro.graph import generators as G


def main():
    # a weighted power-law digraph (RMAT, ~1k vertices)
    g = G.rmat(10, avg_degree=8, directed=True, weighted=True, seed=7)
    print(f"graph: {g.n_vertices} vertices, {int(np.asarray(g.edge_mask).sum())} edges")

    print("\n--- Palgol source (paper Fig. 4) ---")
    print(alg.SSSP.strip())

    cp = compile_program(alg.SSSP, g)
    out, trips, counts = cp.run()
    D = np.asarray(out["D"])
    finite = np.isfinite(D)
    print(f"\nreachable vertices: {finite.sum()}; "
          f"max distance: {D[finite].max():.3f}; iterations: {trips[0]}")

    print("\nsuperstep accounting (paper Table 5 analogue):")
    for k, v in counts.items():
        print(f"  {k:12} {v}")

    # cross-check against the per-vertex reference interpreter
    ref, _ = interpret(alg.SSSP, g)
    assert np.allclose(D, ref["D"], rtol=1e-4, equal_nan=True)
    print("\noracle check: compiled result == naive interpreter ✓")


if __name__ == "__main__":
    main()
