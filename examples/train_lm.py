"""End-to-end LM training driver (deliverable (b) end-to-end example).

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 200
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300   # real hardware

Presets:
  tiny — ~2M params; a few hundred steps run in minutes on this CPU
          container and the loss visibly converges on synthetic Zipf text.
  100m — ~100M-param config (d_model 640, 12 layers, GQA 4:1); the shape
          intended for the "train a ~100M model a few hundred steps" run on
          a real accelerator. Identical code path.

Uses the production substrate end to end: config → synthetic pipeline →
AdamW + cosine schedule → supervised loop with async checkpointing and
failure recovery (see repro.launch.train for the cluster driver).
"""

import argparse
import time

import jax
import numpy as np

from repro.data.pipeline import token_batches
from repro.ft import StragglerMonitor, TrainSupervisor
from repro.models.transformer import TransformerConfig, model as tm
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule

PRESETS = {
    "tiny": TransformerConfig(
        name="tiny-lm", n_layers=4, d_model=128, n_heads=8, n_kv_heads=2,
        d_ff=384, vocab_size=2048, d_head=16,
        param_dtype="float32", compute_dtype="float32",
        attn_chunk_q=64, attn_chunk_kv=64,
    ),
    "100m": TransformerConfig(
        name="lm-100m", n_layers=12, d_model=640, n_heads=10, n_kv_heads=2,
        d_ff=1792, vocab_size=32000, d_head=64,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/train_lm_ckpt")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    n_params = cfg.n_params()
    print(f"model: {cfg.name} ({n_params/1e6:.1f}M params)")

    params = tm.init(jax.random.PRNGKey(0), cfg)
    oc = AdamWConfig(lr=args.lr, weight_decay=0.01)
    state = {"params": params, "opt": adamw_init(params, oc)}
    data = token_batches(args.batch, args.seq, cfg.vocab_size, seed=1)
    batches = [next(data) for _ in range(32)]

    @jax.jit
    def step_fn(state, batch):
        loss, g = jax.value_and_grad(
            lambda p: tm.loss_fn(p, batch, cfg)
        )(state["params"])
        lr_scale = cosine_schedule(
            state["opt"]["step"], warmup=args.steps // 10, total=args.steps
        )
        p, o = adamw_update(g, state["opt"], state["params"], oc, lr_scale)
        return {"params": p, "opt": o}, {"loss": loss}

    losses = []
    t_start = time.perf_counter()

    def logged_step(state, batch):
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        s = len(losses)
        if s % 25 == 0:
            tok_s = s * args.batch * args.seq / (time.perf_counter() - t_start)
            print(f"step {s:4d}  loss {losses[-1]:.4f}  ({tok_s:,.0f} tok/s)")
        return state, m

    sup = TrainSupervisor(
        logged_step,
        lambda i: batches[i % len(batches)],
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        straggler=StragglerMonitor(),
    )
    state, step, metrics = sup.run(state, args.steps)
    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss: {first:.3f} → {last:.3f} over {step} steps "
          f"({'-' if last < first else '+'}{abs(first-last):.3f})")
    assert last < first, "training did not reduce the loss"
    print("converging ✓")


if __name__ == "__main__":
    main()
