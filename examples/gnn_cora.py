"""Full-batch GAT training on a Cora-like graph (gat-cora architecture).

    PYTHONPATH=src python examples/gnn_cora.py

The GNN stack rides the same segment-op substrate as the Pregel runtime —
one GNN layer is one algorithmic superstep (DESIGN.md §5). Trains the
assigned gat-cora config (reduced dims) to high train accuracy on a
synthetic community graph where labels = community id.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.structure import from_edge_list, symmetrize
from repro.models.gnn import GNNConfig, models as gm
from repro.optim import AdamWConfig, adamw_init, adamw_update


def community_graph(n=400, k=4, p_in=0.05, p_out=0.002, d_feat=16, seed=0):
    """Stochastic block model + community-informative features."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, k, n)
    src, dst = [], []
    for i in range(n):
        for j in range(i + 1, n):
            p = p_in if labels[i] == labels[j] else p_out
            if rng.random() < p:
                src.append(i)
                dst.append(j)
    s, d, w = symmetrize(np.array(src), np.array(dst))
    g = from_edge_list(s, d, n, w)
    feats = rng.normal(size=(n, d_feat)).astype(np.float32)
    feats += np.eye(k)[labels] @ rng.normal(size=(k, d_feat)) * 1.5
    return g, jnp.asarray(feats), jnp.asarray(labels.astype(np.int32))


def main():
    g, x, labels = community_graph()
    cfg = GNNConfig(name="gat-cora-demo", variant="gat", n_layers=2,
                    d_hidden=8, n_heads=8, d_in=x.shape[1], n_out=4)
    params = gm.init(jax.random.PRNGKey(0), cfg)
    batch = {
        "x": x, "src": g.src, "dst": g.dst, "emask": g.edge_mask,
        "labels": labels, "lmask": jnp.ones((g.n_vertices,), jnp.float32),
    }
    oc = AdamWConfig(lr=5e-3, weight_decay=0.0)
    st = adamw_init(params, oc)

    @jax.jit
    def step(p, s):
        loss, grads = jax.value_and_grad(
            lambda q: gm.loss_fn(q, batch, cfg)
        )(p)
        p, s = adamw_update(grads, s, p, oc)
        return p, s, loss

    for i in range(200):
        params, st, loss = step(params, st)
        if (i + 1) % 50 == 0:
            logits = gm.forward(params, batch, cfg)
            acc = float(jnp.mean(jnp.argmax(logits, -1) == labels))
            print(f"epoch {i+1:3d}  loss {float(loss):.4f}  acc {acc:.3f}")
    assert acc > 0.8, "GAT failed to learn the communities"
    print("learned the community structure ✓")


if __name__ == "__main__":
    main()
